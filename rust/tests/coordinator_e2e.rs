//! End-to-end coordinator tests: the three-layer stack must return
//! numerically correct, cache-consistent results under concurrent load,
//! for several schemes.
//!
//! The PJRT tests are skipped when `make artifacts` has not run; since the
//! router refactor the sharded tests run on the synthetic backend, so the
//! fleet path (routing, shared batcher, per-shard domains, shutdown
//! semantics) is exercised artifact-free.

use emr::bench_fw::workload::compute_payload;
use emr::coordinator::{Backend, CacheServer, Router, ServerConfig};
use emr::reclaim::Reclaimer;
use emr::util::rng::Xoshiro256;
use std::sync::Arc;

fn have_artifacts() -> bool {
    if emr::runtime::artifacts_available() {
        true
    } else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        false
    }
}

fn concurrent_consistency<R: Reclaimer>() {
    // `with_shards(1)` — the router front-end must reproduce the old
    // single-server behaviour on the unchanged suite.
    let server = Router::<R>::start(
        ServerConfig {
            workers: 2,
            capacity: 500,
            buckets: 64,
            ..ServerConfig::default()
        }
        .with_shards(1),
    )
    .unwrap();
    let server = Arc::new(server);

    // Every client remembers the first answer per key; later answers (hit,
    // or recomputed after eviction) must agree to float tolerance. Not
    // bit-exact: a key recomputed in a different batch-size executable
    // (b1/b8/b32) takes a different reduction order, so low-order bits may
    // differ — cache *hits* are bit-identical, recomputes are ~1e-7 off.
    std::thread::scope(|s| {
        for c in 0..4u64 {
            let server = &server;
            s.spawn(move || {
                let mut rng = Xoshiro256::new(0xE2E2 + c);
                let mut seen: std::collections::HashMap<u32, Box<[f32; 256]>> =
                    std::collections::HashMap::new();
                for _ in 0..300 {
                    let key = rng.below(100) as u32;
                    let resp = server.request(key).expect("request");
                    assert!(resp.data.iter().all(|v| v.is_finite() && v.abs() <= 1.0));
                    match seen.get(&key) {
                        Some(prev) => {
                            for (i, (a, b)) in prev.iter().zip(resp.data.iter()).enumerate() {
                                assert!(
                                    (a - b).abs() < 1e-5,
                                    "{}: key {key} lane {i} changed: {a} vs {b}",
                                    R::NAME
                                );
                            }
                        }
                        None => {
                            seen.insert(key, resp.data);
                        }
                    }
                }
            });
        }
    });

    let m = server.metrics();
    assert_eq!(m.requests, 4 * 300);
    assert!(m.hits > 0, "some requests must hit");
    assert!(m.misses > 0, "some requests must miss");
    assert!(m.batches > 0);
    server.shutdown();
}

#[test]
fn stamp_it_serves_consistently() {
    if !have_artifacts() {
        return;
    }
    concurrent_consistency::<emr::reclaim::stamp::StampIt>();
}

#[test]
fn ebr_serves_consistently() {
    if !have_artifacts() {
        return;
    }
    concurrent_consistency::<emr::reclaim::ebr::Ebr>();
}

#[test]
fn hp_serves_consistently() {
    if !have_artifacts() {
        return;
    }
    concurrent_consistency::<emr::reclaim::hp::Hp>();
}

#[test]
fn hyaline_serves_consistently() {
    if !have_artifacts() {
        return;
    }
    concurrent_consistency::<emr::reclaim::hyaline::Hyaline>();
}

#[test]
fn server_results_match_direct_engine() {
    if !have_artifacts() {
        return;
    }
    // The coordinator must be a pure cache over the engine: responses equal
    // direct engine output for the same seed.
    let engine =
        emr::runtime::Engine::load(&emr::runtime::default_artifact_dir()).expect("engine");
    let direct = engine.execute(&[123, 456]).unwrap();

    let server = CacheServer::<emr::reclaim::stamp::StampIt>::start(ServerConfig {
        workers: 1,
        ..ServerConfig::default()
    })
    .unwrap();
    for (seed, want) in [(123u32, &direct[0]), (456u32, &direct[1])] {
        let resp = server.request(seed).unwrap();
        for (a, b) in resp.data.iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-6, "seed {seed}: {a} vs {b}");
        }
    }
    server.shutdown();
}

#[test]
fn eviction_keeps_serving_correctly() {
    if !have_artifacts() {
        return;
    }
    // Tiny capacity forces constant eviction; answers must stay correct.
    let server = CacheServer::<emr::reclaim::lfrc::Lfrc>::start(ServerConfig {
        workers: 2,
        capacity: 8,
        buckets: 4,
        ..ServerConfig::default()
    })
    .unwrap();
    let first = server.request(7).unwrap();
    for key in 0..64u32 {
        let _ = server.request(key).unwrap();
    }
    let again = server.request(7).unwrap();
    for (a, b) in first.data.iter().zip(again.data.iter()) {
        // Tolerance: recomputation may use a different batch executable
        // (different reduction order) — see concurrent_consistency.
        assert!((a - b).abs() < 1e-5, "recomputed result differs: {a} vs {b}");
    }
    assert!(server.cache_len() <= 12);
    server.shutdown();
}

// ---- Sharded-router suite (synthetic backend: runs without artifacts) ----

fn synthetic_cfg() -> ServerConfig {
    ServerConfig {
        workers: 2,
        capacity: 128,
        buckets: 32,
        ..ServerConfig::default()
    }
    .with_backend(Backend::synthetic())
}

fn sharded_consistency<R: Reclaimer>(shards: usize, shared_domain: bool) {
    let server = Router::<R>::start(
        synthetic_cfg().with_shards(shards).with_shared_domain(shared_domain),
    )
    .unwrap();
    std::thread::scope(|s| {
        for c in 0..4u64 {
            let server = &server;
            s.spawn(move || {
                let mut rng = Xoshiro256::new(0x5A4D + c);
                for _ in 0..300 {
                    let key = rng.below(100) as u32;
                    let resp = server.request(key).expect("request");
                    // Synthetic results are exactly reproducible.
                    assert_eq!(
                        resp.data[..],
                        compute_payload(key as u64)[..],
                        "{}: wrong payload for key {key}",
                        R::NAME
                    );
                }
            });
        }
    });
    let m = server.metrics();
    assert_eq!(m.requests, 4 * 300);
    assert_eq!(m.hits + m.misses, 4 * 300);
    assert!(m.batches > 0);
    let per_shard = server.shard_metrics();
    assert_eq!(per_shard.len(), shards);
    assert_eq!(per_shard.iter().map(|s| s.requests).sum::<u64>(), 4 * 300);
    server.shutdown();
}

#[test]
fn sharded_router_serves_consistently_stamp() {
    sharded_consistency::<emr::reclaim::stamp::StampIt>(4, false);
}

#[test]
fn sharded_router_serves_consistently_shared_domain() {
    sharded_consistency::<emr::reclaim::ebr::Ebr>(4, true);
}

#[test]
fn sharded_router_serves_consistently_hp() {
    sharded_consistency::<emr::reclaim::hp::Hp>(2, false);
}

#[test]
fn sharded_router_serves_consistently_hyaline() {
    sharded_consistency::<emr::reclaim::hyaline::Hyaline>(4, false);
}

#[test]
fn sharded_router_serves_consistently_hyaline_shared_domain() {
    sharded_consistency::<emr::reclaim::hyaline::Hyaline>(2, true);
}

#[test]
fn routing_is_deterministic_across_restarts() {
    // Same key → same shard, across two independent router instances (the
    // hash is a pure function of key and shard count — nothing per-process
    // seeds it).
    let keys: Vec<u32> = (0..512u32).map(|i| i.wrapping_mul(2_654_435_761)).collect();
    let a = Router::<emr::reclaim::stamp::StampIt>::start(synthetic_cfg().with_shards(4)).unwrap();
    let map_a: Vec<usize> = keys.iter().map(|&k| a.shard_of(k)).collect();
    a.shutdown();
    drop(a);
    let b = Router::<emr::reclaim::stamp::StampIt>::start(synthetic_cfg().with_shards(4)).unwrap();
    let map_b: Vec<usize> = keys.iter().map(|&k| b.shard_of(k)).collect();
    assert_eq!(map_a, map_b, "routing must be deterministic across restarts");
    // And the hash actually spreads: every shard owns some keys.
    for shard in 0..4 {
        assert!(map_a.contains(&shard), "shard {shard} owns no keys");
    }
    b.shutdown();
}

#[test]
fn cross_shard_domains_never_share_retire_lists() {
    // Satellite: drive eviction churn onto shard 0 only (keys filtered by
    // the router's own mapping) and verify shard 1's domain never observes
    // a retire. Tiny capacity forces constant eviction → constant retiring
    // through shard 0's domain.
    let server = Router::<emr::reclaim::stamp::StampIt>::start(
        ServerConfig {
            workers: 1,
            capacity: 8,
            buckets: 4,
            ..ServerConfig::default()
        }
        .with_backend(Backend::synthetic())
        .with_shards(2),
    )
    .unwrap();
    let shard0_keys: Vec<u32> = (0..4096u32).filter(|&k| server.shard_of(k) == 0).collect();
    assert!(shard0_keys.len() > 64, "need enough shard-0 keys to churn");
    for &key in shard0_keys.iter().take(256) {
        let _ = server.request(key).unwrap();
    }
    let per_shard = server.shard_metrics();
    assert_eq!(per_shard[0].requests, 256);
    assert_eq!(per_shard[1].requests, 0, "no traffic may leak to shard 1");
    assert!(
        per_shard[0].misses > 8,
        "churn must miss (evicting through shard 0's domain)"
    );
    // Shard 1's domain never saw a retire: its unreclaimed count is 0 no
    // matter how many nodes shard 0 parked.
    assert_eq!(
        per_shard[1].unreclaimed_nodes, 0,
        "shard 1's domain must be unaffected by shard 0's retires"
    );
    assert_eq!(server.shards()[1].cache_len(), 0);
    server.shutdown();
}

// ---- Engine-group suite (synthetic backend: runs without artifacts) ----

#[test]
fn grouped_routing_is_deterministic_across_restarts() {
    // Same key → same shard → same group, across two independent router
    // instances: shard_for_key and group_for_shard are both pure functions
    // of the key and the (shards, groups) shape — nothing per-process
    // seeds them.
    let keys: Vec<u32> = (0..512u32).map(|i| i.wrapping_mul(2_654_435_761)).collect();
    let start = || {
        Router::<emr::reclaim::stamp::StampIt>::start(
            synthetic_cfg().with_shards(8).with_groups(4),
        )
        .unwrap()
    };
    let a = start();
    let map_a: Vec<(usize, usize)> = keys.iter().map(|&k| (a.shard_of(k), a.group_of(k))).collect();
    // The group partition itself: every shard in exactly one group.
    let mut owned: Vec<usize> = (0..4).flat_map(|g| a.group_shards(g)).collect();
    owned.sort_unstable();
    assert_eq!(owned, (0..8).collect::<Vec<_>>(), "groups must partition the shards");
    a.shutdown();
    drop(a);
    let b = start();
    let map_b: Vec<(usize, usize)> = keys.iter().map(|&k| (b.shard_of(k), b.group_of(k))).collect();
    assert_eq!(map_a, map_b, "key→shard→group must be deterministic across restarts");
    // And every group owns some keys.
    for g in 0..4 {
        assert!(map_a.iter().any(|&(_, grp)| grp == g), "group {g} owns no keys");
    }
    b.shutdown();
}

#[test]
fn stalled_group_cannot_wedge_another_group() {
    // Cross-group miss isolation: a wedged engine in one group must not
    // delay another group's misses. shards=2, groups=2 → shard 0 is group
    // 0, shard 1 is group 1. The stall backend makes any batch containing
    // `stall_key` sleep 3 s — only group 0 ever sees that key, so group
    // 1's batcher must keep answering at normal speed while group 0 is
    // asleep inside execute.
    use std::time::{Duration, Instant};
    let probe =
        Router::<emr::reclaim::stamp::StampIt>::start(synthetic_cfg().with_shards(2)).unwrap();
    let stall_key = (0..4096u32).find(|&k| probe.shard_of(k) == 0).unwrap();
    let other_keys: Vec<u32> =
        (0..4096u32).filter(|&k| probe.shard_of(k) == 1).take(32).collect();
    probe.shutdown();
    drop(probe);

    const STALL: Duration = Duration::from_secs(3);
    let server = Router::<emr::reclaim::stamp::StampIt>::start(
        synthetic_cfg()
            .with_shards(2)
            .with_groups(2)
            .with_backend(Backend::SyntheticStall { key: stall_key, delay_ms: 3000 }),
    )
    .unwrap();
    assert_eq!(server.group_of(stall_key), 0);

    // Wedge group 0: its batcher picks the miss up within batch_wait and
    // goes to sleep inside execute for the full stall.
    let stalled = server.submit(stall_key);
    std::thread::sleep(Duration::from_millis(100));

    // Group 1 must be unaffected: all its misses complete well inside the
    // stall window (the single-batcher fleet would serialize them behind
    // the sleeping execute).
    let t0 = Instant::now();
    for &k in &other_keys {
        let resp = server.request(k).expect("group-1 request during group-0 stall");
        assert_eq!(resp.data[..], compute_payload(k as u64)[..]);
    }
    let elapsed = t0.elapsed();
    assert!(
        elapsed < STALL / 2,
        "group 1 stalled behind group 0's engine: {elapsed:?} (stall {STALL:?})"
    );

    // The wedged request itself still completes once the stall ends.
    let resp = stalled.recv().expect("stalled request eventually completes");
    assert_eq!(resp.data[..], compute_payload(stall_key as u64)[..]);

    let per_group = server.group_metrics();
    assert!(per_group[0].batches >= 1, "group 0 dispatched: {:?}", per_group[0]);
    assert!(per_group[1].batches >= 1, "group 1 dispatched: {:?}", per_group[1]);
    assert_eq!(server.metrics().engine_errors, 0);
    server.shutdown();
}

#[test]
fn engine_failure_is_counted_and_fails_fast() {
    // Satellite (batcher failure path): an engine.execute failure must
    // count in `engine_errors` AND close the affected completion slots so
    // waiters error immediately — not hang until the 30 s recv deadline.
    use std::time::{Duration, Instant};
    let server = Router::<emr::reclaim::ebr::Ebr>::start(
        synthetic_cfg().with_backend(Backend::SyntheticFailing),
    )
    .unwrap();
    let t0 = Instant::now();
    let err = server.request(5);
    let elapsed = t0.elapsed();
    assert!(err.is_err(), "a failed batch must surface as an error");
    assert!(
        elapsed < Duration::from_secs(5),
        "waiter must resolve on slot close, not recv timeout: {elapsed:?}"
    );
    // The async path resolves the same way.
    assert!(emr::runtime::exec::block_on(server.submit_async(6)).is_err());
    // The batcher survives its engine's failures and keeps counting.
    assert!(server.request(7).is_err());
    let m = server.metrics();
    assert!(m.engine_errors >= 3, "every failed dispatch counts: {m}");
    assert_eq!(m.hits, 0);
    assert_eq!(m.in_flight, 0, "failed requests must close their in-flight tokens");
    server.shutdown();
}

fn group_shutdown_drains<R: Reclaimer>() {
    // Graceful shutdown with groups: concurrent load over a 6-shard,
    // 3-group fleet, then shutdown must drain every group's batcher (all
    // gauges settle to zero, stragglers rejected) — for Stamp-it, HP, EBR
    // and Hyaline alike.
    let server =
        Router::<R>::start(synthetic_cfg().with_shards(6).with_groups(3)).unwrap();
    std::thread::scope(|s| {
        for c in 0..4u64 {
            let server = &server;
            s.spawn(move || {
                let mut rng = Xoshiro256::new(0x96D + c);
                for _ in 0..200 {
                    let key = rng.below(400) as u32;
                    let resp = server.request(key).expect("request");
                    assert_eq!(
                        resp.data[..],
                        compute_payload(key as u64)[..],
                        "{}: wrong payload for key {key}",
                        R::NAME
                    );
                }
            });
        }
    });
    let per_group = server.group_metrics();
    assert_eq!(per_group.len(), 3);
    for g in &per_group {
        assert!(g.batches >= 1, "{}: group {} batcher never dispatched", R::NAME, g.group);
    }
    server.shutdown();
    let m = server.metrics();
    assert_eq!(m.requests, 4 * 200);
    assert_eq!(m.queue_depth, 0, "{}: queue must drain on shutdown", R::NAME);
    assert_eq!(m.in_flight, 0, "{}: all completion slots must settle", R::NAME);
    assert!(server.request(1).is_err(), "{}: stragglers are rejected", R::NAME);
    // Idempotent shutdown stays safe with multiple batchers too.
    server.shutdown();
}

#[test]
fn group_shutdown_drains_stamp() {
    group_shutdown_drains::<emr::reclaim::stamp::StampIt>();
}

#[test]
fn group_shutdown_drains_hp() {
    group_shutdown_drains::<emr::reclaim::hp::Hp>();
}

#[test]
fn group_shutdown_drains_ebr() {
    group_shutdown_drains::<emr::reclaim::ebr::Ebr>();
}

#[test]
fn group_shutdown_drains_hyaline() {
    group_shutdown_drains::<emr::reclaim::hyaline::Hyaline>();
}

#[test]
fn shutdown_rejects_straggler_submits() {
    // Regression (satellite): a request submitted after shutdown must see
    // a closed completion slot, not block forever — on the blocking handle
    // and on the raw future alike.
    let server = Router::<emr::reclaim::ebr::Ebr>::start(synthetic_cfg()).unwrap();
    let _ = server.request(9).unwrap();
    server.shutdown();
    assert!(server.request(10).is_err());
    assert!(server.submit(11).recv().is_err());
    assert!(emr::runtime::exec::block_on(server.submit_async(12)).is_err());
    // Idempotent shutdown stays safe.
    server.shutdown();
}

//! End-to-end coordinator tests (skipped when `make artifacts` has not
//! run): the three-layer stack must return numerically correct, cache-
//! consistent results under concurrent load, for several schemes.

use emr::coordinator::{CacheServer, ServerConfig};
use emr::reclaim::Reclaimer;
use emr::util::rng::Xoshiro256;
use std::sync::Arc;

fn have_artifacts() -> bool {
    if emr::runtime::artifacts_available() {
        true
    } else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        false
    }
}

fn concurrent_consistency<R: Reclaimer>() {
    let server = CacheServer::<R>::start(ServerConfig {
        workers: 2,
        capacity: 500,
        buckets: 64,
        ..ServerConfig::default()
    })
    .unwrap();
    let server = Arc::new(server);

    // Every client remembers the first answer per key; later answers (hit,
    // or recomputed after eviction) must agree to float tolerance. Not
    // bit-exact: a key recomputed in a different batch-size executable
    // (b1/b8/b32) takes a different reduction order, so low-order bits may
    // differ — cache *hits* are bit-identical, recomputes are ~1e-7 off.
    std::thread::scope(|s| {
        for c in 0..4u64 {
            let server = &server;
            s.spawn(move || {
                let mut rng = Xoshiro256::new(0xE2E2 + c);
                let mut seen: std::collections::HashMap<u32, Box<[f32; 256]>> =
                    std::collections::HashMap::new();
                for _ in 0..300 {
                    let key = rng.below(100) as u32;
                    let resp = server.request(key).expect("request");
                    assert!(resp.data.iter().all(|v| v.is_finite() && v.abs() <= 1.0));
                    match seen.get(&key) {
                        Some(prev) => {
                            for (i, (a, b)) in prev.iter().zip(resp.data.iter()).enumerate() {
                                assert!(
                                    (a - b).abs() < 1e-5,
                                    "{}: key {key} lane {i} changed: {a} vs {b}",
                                    R::NAME
                                );
                            }
                        }
                        None => {
                            seen.insert(key, resp.data);
                        }
                    }
                }
            });
        }
    });

    let m = server.metrics();
    assert_eq!(m.requests, 4 * 300);
    assert!(m.hits > 0, "some requests must hit");
    assert!(m.misses > 0, "some requests must miss");
    assert!(m.batches > 0);
    server.shutdown();
}

#[test]
fn stamp_it_serves_consistently() {
    if !have_artifacts() {
        return;
    }
    concurrent_consistency::<emr::reclaim::stamp::StampIt>();
}

#[test]
fn ebr_serves_consistently() {
    if !have_artifacts() {
        return;
    }
    concurrent_consistency::<emr::reclaim::ebr::Ebr>();
}

#[test]
fn hp_serves_consistently() {
    if !have_artifacts() {
        return;
    }
    concurrent_consistency::<emr::reclaim::hp::Hp>();
}

#[test]
fn server_results_match_direct_engine() {
    if !have_artifacts() {
        return;
    }
    // The coordinator must be a pure cache over the engine: responses equal
    // direct engine output for the same seed.
    let engine =
        emr::runtime::Engine::load(&emr::runtime::default_artifact_dir()).expect("engine");
    let direct = engine.execute(&[123, 456]).unwrap();

    let server = CacheServer::<emr::reclaim::stamp::StampIt>::start(ServerConfig {
        workers: 1,
        ..ServerConfig::default()
    })
    .unwrap();
    for (seed, want) in [(123u32, &direct[0]), (456u32, &direct[1])] {
        let resp = server.request(seed).unwrap();
        for (a, b) in resp.data.iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-6, "seed {seed}: {a} vs {b}");
        }
    }
    server.shutdown();
}

#[test]
fn eviction_keeps_serving_correctly() {
    if !have_artifacts() {
        return;
    }
    // Tiny capacity forces constant eviction; answers must stay correct.
    let server = CacheServer::<emr::reclaim::lfrc::Lfrc>::start(ServerConfig {
        workers: 2,
        capacity: 8,
        buckets: 4,
        ..ServerConfig::default()
    })
    .unwrap();
    let first = server.request(7).unwrap();
    for key in 0..64u32 {
        let _ = server.request(key).unwrap();
    }
    let again = server.request(7).unwrap();
    for (a, b) in first.data.iter().zip(again.data.iter()) {
        // Tolerance: recomputation may use a different batch executable
        // (different reduction order) — see concurrent_consistency.
        assert!((a - b).abs() < 1e-5, "recomputed result differs: {a} vs {b}");
    }
    assert!(server.cache_len() <= 12);
    server.shutdown();
}

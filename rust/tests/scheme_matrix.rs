//! Conformance matrix: every scheme × every shared exercise × every data
//! structure. A new scheme only has to pass this file to be trusted by the
//! benchmarks.
//!
//! Everything here runs through the **safe facade** (`Atomic` / `Guard` /
//! `Shared` / `Owned`, and the `HandleSource`-generic ds entry points):
//! structure roundtrips run twice — once with `Cached` on the **global**
//! domain (the quickstart TLS path) and once with an explicit handle in
//! an **owned** domain (the isolated, TLS-free fast path) — and the
//! `facade_roundtrip` exercise drives `Owned` disposal, CAS publication,
//! branded `Shared` reads and both retire paths for all 9 schemes.

use emr::ds::hashmap::FifoCache;
use emr::ds::list::List;
use emr::ds::queue::Queue;
use emr::reclaim::tests_common::*;
use emr::reclaim::{Cached, DomainRef, HandleSource, Reclaimer, Region};

fn queue_roundtrip<R: Reclaimer>(q: Queue<u64, R>, h: impl HandleSource<R>) {
    for i in 0..1000 {
        q.enqueue(h, i);
    }
    for i in 0..1000 {
        assert_eq!(q.dequeue(h), Some(i), "{}: FIFO order broken", R::NAME);
    }
    assert_eq!(q.dequeue(h), None);
}

fn list_roundtrip<R: Reclaimer>(l: List<u64, u64, R>, h: impl HandleSource<R>) {
    for k in 0..200u64 {
        assert!(l.insert(h, k, k * 3));
    }
    assert_eq!(l.len(h), 200);
    for k in 0..200u64 {
        assert_eq!(l.get(h, &k, |v| *v), Some(k * 3), "{}", R::NAME);
    }
    for k in (0..200u64).step_by(2) {
        assert!(l.remove(h, &k));
    }
    assert_eq!(l.len(h), 100);
    assert!(!l.contains(h, &0));
    assert!(l.contains(h, &1));
}

fn cache_roundtrip<R: Reclaimer>(c: FifoCache<u64, [u8; 128], R>, h: impl HandleSource<R>) {
    for k in 0..200u64 {
        c.insert(h, k, [k as u8; 128]);
    }
    assert!(c.len() <= 50, "{}: capacity violated ({})", R::NAME, c.len());
    assert!(c.contains(h, &199));
    assert!(!c.contains(h, &0));
}

fn region_nesting<R: Reclaimer>() {
    // Regions are reentrant; guards nest within regions. Handle-based…
    let domain = DomainRef::<R>::new_owned();
    let h = domain.register();
    let _outer = Region::enter(&h);
    {
        let _inner = Region::enter(&h);
        let _third = Region::enter(&h);
    }
    let _after = Region::enter(&h);
    // …and via the global-domain TLS convenience path.
    let _global = Region::<R>::enter_global();
}

macro_rules! matrix {
    ($mod_name:ident, $scheme:ty) => {
        mod $mod_name {
            use super::*;

            #[test]
            fn basic_reclamation() {
                exercise_basic_reclamation::<$scheme>();
            }

            #[test]
            fn guard_blocks_reclamation() {
                exercise_guard_blocks_reclamation::<$scheme>();
            }

            #[test]
            fn region_guard() {
                exercise_region_guard::<$scheme>();
            }

            #[test]
            fn facade_roundtrip() {
                exercise_facade::<$scheme>();
            }

            #[test]
            fn domain_isolation() {
                exercise_domain_isolation::<$scheme>();
            }

            #[test]
            fn concurrent_swap_storm() {
                exercise_concurrent_smoke::<$scheme>(4, 400);
            }

            #[test]
            fn queue_global_domain() {
                let q: Queue<u64, $scheme> = Queue::new();
                queue_roundtrip(q, Cached);
            }

            #[test]
            fn queue_owned_domain() {
                let q: Queue<u64, $scheme> = Queue::new_in(DomainRef::new_owned());
                let h = q.domain().register();
                queue_roundtrip(q, &h);
            }

            #[test]
            fn list_global_domain() {
                let l: List<u64, u64, $scheme> = List::new();
                list_roundtrip(l, Cached);
            }

            #[test]
            fn list_owned_domain() {
                let l: List<u64, u64, $scheme> = List::new_in(DomainRef::new_owned());
                let h = l.domain().register();
                list_roundtrip(l, &h);
            }

            #[test]
            fn cache_global_domain() {
                let c: FifoCache<u64, [u8; 128], $scheme> = FifoCache::new(32, 50);
                cache_roundtrip(c, Cached);
            }

            #[test]
            fn cache_owned_domain() {
                let c: FifoCache<u64, [u8; 128], $scheme> =
                    FifoCache::new_in(DomainRef::new_owned(), 32, 50);
                let h = c.domain().register();
                cache_roundtrip(c, &h);
            }

            #[test]
            fn regions_nest() {
                region_nesting::<$scheme>();
            }
        }
    };
}

// Leaky never reclaims by design — it only has to pass the structural
// tests (including the structural half of the facade surface), not the
// reclamation exercises.
mod leaky {
    use super::*;
    use emr::reclaim::{Atomic, Guard, MarkedPtr, Owned};
    type Leaky = emr::reclaim::leaky::Leaky;

    #[test]
    fn queue() {
        let q: Queue<u64, Leaky> = Queue::new();
        queue_roundtrip(q, Cached);
    }

    #[test]
    fn list() {
        let l: List<u64, u64, Leaky> = List::new();
        list_roundtrip(l, Cached);
    }

    #[test]
    fn cache() {
        let c: FifoCache<u64, [u8; 128], Leaky> = FifoCache::new(32, 50);
        cache_roundtrip(c, Cached);
    }

    #[test]
    fn regions_nest() {
        region_nesting::<Leaky>();
    }

    #[test]
    fn facade_structural() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let domain = DomainRef::<Leaky>::new_owned();
        let h = domain.register();
        let drops = Arc::new(AtomicUsize::new(0));
        // Owned drop frees even under the never-reclaiming baseline (the
        // node was never published, so no reclamation protocol runs).
        drop(Owned::<Payload, Leaky>::new(Payload::new(1, &drops)));
        assert_eq!(drops.load(Ordering::Relaxed), 1);
        // Publish → protect → branded read; CAS publication returns the
        // loser on failure.
        let cell: Atomic<Payload, Leaky> = Atomic::new(Owned::new(Payload::new(2, &drops)));
        let occupant = cell.load(Ordering::Relaxed);
        let loser = Owned::new(Payload::new(3, &drops));
        let (witness, loser) = cell
            .cas_publish(MarkedPtr::null(), loser, Ordering::AcqRel, Ordering::Acquire)
            .expect_err("cell occupied");
        assert!(witness == occupant);
        drop(loser); // frees node 3
        assert_eq!(drops.load(Ordering::Relaxed), 2);
        let mut g: Guard<Payload, Leaky> = h.guard();
        assert_eq!(g.protect(&cell).expect("non-null").read(), 2);
        // Leaky leaks node 2 by design; the counters record it honestly.
    }
}

matrix!(lfrc, emr::reclaim::lfrc::Lfrc);
matrix!(hp, emr::reclaim::hp::Hp);
matrix!(ebr, emr::reclaim::ebr::Ebr);
matrix!(nebr, emr::reclaim::nebr::Nebr);
matrix!(qsr, emr::reclaim::qsr::Qsr);
matrix!(debra, emr::reclaim::debra::Debra);
matrix!(stamp, emr::reclaim::stamp::StampIt);
matrix!(hyaline, emr::reclaim::hyaline::Hyaline);

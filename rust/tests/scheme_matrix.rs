//! Conformance matrix: every scheme × every shared exercise × every data
//! structure. A new scheme only has to pass this file to be trusted by the
//! benchmarks.
//!
//! Structure roundtrips run twice: once on the **global** domain (the
//! quickstart TLS path) and once in an **owned** domain (the isolated
//! fast path) — both plumbing variants must behave identically.

use emr::ds::hashmap::FifoCache;
use emr::ds::list::List;
use emr::ds::queue::Queue;
use emr::reclaim::tests_common::*;
use emr::reclaim::{DomainRef, Reclaimer, Region};

fn queue_roundtrip<R: Reclaimer>(q: Queue<u64, R>) {
    for i in 0..1000 {
        q.enqueue(i);
    }
    for i in 0..1000 {
        assert_eq!(q.dequeue(), Some(i), "{}: FIFO order broken", R::NAME);
    }
    assert_eq!(q.dequeue(), None);
}

fn list_roundtrip<R: Reclaimer>(l: List<u64, u64, R>) {
    for k in 0..200u64 {
        assert!(l.insert(k, k * 3));
    }
    assert_eq!(l.len(), 200);
    for k in 0..200u64 {
        assert_eq!(l.get_with(&k, |v| *v), Some(k * 3), "{}", R::NAME);
    }
    for k in (0..200u64).step_by(2) {
        assert!(l.remove(&k));
    }
    assert_eq!(l.len(), 100);
    assert!(!l.contains(&0));
    assert!(l.contains(&1));
}

fn cache_roundtrip<R: Reclaimer>(c: FifoCache<u64, [u8; 128], R>) {
    for k in 0..200u64 {
        c.insert(k, [k as u8; 128]);
    }
    assert!(c.len() <= 50, "{}: capacity violated ({})", R::NAME, c.len());
    assert!(c.contains(&199));
    assert!(!c.contains(&0));
}

fn region_nesting<R: Reclaimer>() {
    // Regions are reentrant; guards nest within regions. Handle-based…
    let domain = DomainRef::<R>::new_owned();
    let h = domain.register();
    let _outer = Region::enter(&h);
    {
        let _inner = Region::enter(&h);
        let _third = Region::enter(&h);
    }
    let _after = Region::enter(&h);
    // …and via the global-domain TLS convenience path.
    let _global = Region::<R>::enter_global();
}

macro_rules! matrix {
    ($mod_name:ident, $scheme:ty) => {
        mod $mod_name {
            use super::*;

            #[test]
            fn basic_reclamation() {
                exercise_basic_reclamation::<$scheme>();
            }

            #[test]
            fn guard_blocks_reclamation() {
                exercise_guard_blocks_reclamation::<$scheme>();
            }

            #[test]
            fn region_guard() {
                exercise_region_guard::<$scheme>();
            }

            #[test]
            fn domain_isolation() {
                exercise_domain_isolation::<$scheme>();
            }

            #[test]
            fn concurrent_swap_storm() {
                exercise_concurrent_smoke::<$scheme>(4, 400);
            }

            #[test]
            fn queue_global_domain() {
                queue_roundtrip::<$scheme>(Queue::new());
            }

            #[test]
            fn queue_owned_domain() {
                queue_roundtrip::<$scheme>(Queue::new_in(DomainRef::new_owned()));
            }

            #[test]
            fn list_global_domain() {
                list_roundtrip::<$scheme>(List::new());
            }

            #[test]
            fn list_owned_domain() {
                list_roundtrip::<$scheme>(List::new_in(DomainRef::new_owned()));
            }

            #[test]
            fn cache_global_domain() {
                cache_roundtrip::<$scheme>(FifoCache::new(32, 50));
            }

            #[test]
            fn cache_owned_domain() {
                cache_roundtrip::<$scheme>(FifoCache::new_in(DomainRef::new_owned(), 32, 50));
            }

            #[test]
            fn regions_nest() {
                region_nesting::<$scheme>();
            }
        }
    };
}

// Leaky never reclaims by design — it only has to pass the structural
// tests, not the reclamation exercises.
mod leaky {
    use super::*;
    type Leaky = emr::reclaim::leaky::Leaky;

    #[test]
    fn queue() {
        queue_roundtrip::<Leaky>(Queue::new());
    }

    #[test]
    fn list() {
        list_roundtrip::<Leaky>(List::new());
    }

    #[test]
    fn cache() {
        cache_roundtrip::<Leaky>(FifoCache::new(32, 50));
    }

    #[test]
    fn regions_nest() {
        region_nesting::<Leaky>();
    }
}

matrix!(lfrc, emr::reclaim::lfrc::Lfrc);
matrix!(hp, emr::reclaim::hp::Hp);
matrix!(ebr, emr::reclaim::ebr::Ebr);
matrix!(nebr, emr::reclaim::nebr::Nebr);
matrix!(qsr, emr::reclaim::qsr::Qsr);
matrix!(debra, emr::reclaim::debra::Debra);
matrix!(stamp, emr::reclaim::stamp::StampIt);

//! Integration tests of the flight recorder (DESIGN.md §10): concurrent
//! ring stress, snapshot round-trips through the on-disk dump format, the
//! chained/idempotent panic hook, and the end-to-end crash path — a
//! `repro serve --crash-test` run whose injected worker panic must leave
//! a parseable crash dump behind.
//!
//! Tracing state (the enabled flag, ring capacity, the panic hook) is
//! process-global, so every test that touches it serializes on [`LOCK`].

use emr::trace;
use std::sync::Mutex;

/// Serializes tests that flip process-global trace state.
static LOCK: Mutex<()> = Mutex::new(());

/// Hold the lock even if a previous holder panicked.
fn lock() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[test]
fn concurrent_writers_drain_without_torn_events() {
    let _g = lock();
    trace::set_enabled(true);
    const WRITERS: usize = 4;
    const PER_WRITER: u32 = 20_000;

    let mut drainer = trace::Drainer::from_now();
    const WRITER_LABELS: [&str; WRITERS] =
        ["test.stress.w0", "test.stress.w1", "test.stress.w2", "test.stress.w3"];
    let labels: Vec<u16> = WRITER_LABELS.iter().map(|&n| trace::intern(n)).collect();

    // Writers hammer their own rings while this thread drains
    // concurrently — the seqlock must hand the drainer only fully
    // published events (arg always echoes a value the writer stored
    // under that label, never a mix of two slots).
    let mut harvested: Vec<Vec<u32>> = vec![Vec::new(); WRITERS];
    let mut lost = 0u64;
    std::thread::scope(|scope| {
        for (w, &label) in labels.iter().enumerate() {
            scope.spawn(move || {
                for i in 0..PER_WRITER {
                    trace::event!("test.stress.pad"); // unrelated traffic
                    trace::emit(label, (w as u32) << 24 | i);
                }
            });
        }
        loop {
            let d = drainer.drain();
            lost += d.lost;
            let mut saw_any = false;
            for e in &d.events {
                if let Some(w) = labels.iter().position(|&l| l == e.label) {
                    assert_eq!(
                        e.arg >> 24,
                        w as u32,
                        "event under writer {w}'s label carries another writer's arg — torn read"
                    );
                    harvested[w].push(e.arg & 0x00FF_FFFF);
                    saw_any = true;
                }
            }
            let done: usize = harvested.iter().map(Vec::len).sum();
            if !saw_any && done as u64 + lost >= (WRITERS as u64) * PER_WRITER as u64 {
                break;
            }
            std::thread::yield_now();
        }
    });

    // Overwrite-oldest accounting: every emitted event was either
    // harvested exactly once or counted as lost — none invented, none
    // double-drained. Per-writer sequences must stay strictly ascending
    // (a ring is FIFO per producer; drains preserve position order).
    for (w, seen) in harvested.iter().enumerate() {
        assert!(
            seen.windows(2).all(|p| p[0] < p[1]),
            "writer {w}'s drained args not strictly ascending: duplicate or reordered event"
        );
    }
    let drained: u64 = harvested.iter().map(|v| v.len() as u64).sum();
    assert!(
        drained <= (WRITERS as u64) * PER_WRITER as u64,
        "drained more distinct events than were emitted"
    );
    assert!(drained > 0, "stress run drained nothing");
}

#[test]
fn tiny_ring_overwrites_oldest_but_keeps_newest() {
    let _g = lock();
    trace::apply_knob(64); // rings created after this are 64 slots
    let label = trace::intern("test.tiny_ring");
    let (harvested, lost) = std::thread::spawn(move || {
        // Fresh thread → fresh ring at the tiny capacity.
        let mut d = trace::Drainer::from_now();
        for i in 0..1000u32 {
            trace::emit(label, i);
        }
        let d = d.drain();
        let mine: Vec<u32> =
            d.events.iter().filter(|e| e.label == label).map(|e| e.arg).collect();
        (mine, d.lost)
    })
    .join()
    .unwrap();
    // The newest events survive; everything older was overwritten and
    // shows up in the lost count rather than vanishing silently.
    assert!(harvested.len() <= 64);
    assert_eq!(harvested.last(), Some(&999));
    assert!(lost >= 1000 - 64, "overwrites must be accounted as lost");
    assert!(
        harvested.windows(2).all(|p| p[0] < p[1]),
        "resident tail must be in emission order"
    );
    trace::apply_knob(trace::DEFAULT_RING_CAP); // restore for other tests
}

#[test]
fn snapshot_round_trips_through_dump_file() {
    let _g = lock();
    trace::set_enabled(true);
    let label = trace::intern("test.snapshot.integration");
    for i in 0..200u32 {
        trace::emit(label, i);
    }
    let path = std::env::temp_dir().join(format!("emr-trace-it-{}.bin", std::process::id()));
    let info = trace::write_snapshot(&path, None).unwrap();
    assert!(info.events >= 200);

    let dump = trace::read_dump(&path).unwrap();
    assert!(dump.events.windows(2).all(|w| w[0].ts <= w[1].ts), "dump must be ts-sorted");
    let mine: Vec<u32> = dump
        .events
        .iter()
        .filter(|e| dump.label(e) == "test.snapshot.integration")
        .map(|e| e.arg)
        .collect();
    assert_eq!(mine, (0..200).collect::<Vec<_>>());

    // Both render paths of `repro trace view` resolve the embedded
    // label table, not the process-local interner.
    assert!(dump.to_text().contains("test.snapshot.integration"));
    assert!(dump.to_json().contains("\"label\": \"test.snapshot.integration\""));
    let _ = std::fs::remove_file(&path);
}

#[test]
fn panic_hook_chains_and_is_idempotent() {
    use std::sync::atomic::{AtomicU32, Ordering};
    let _g = lock();
    trace::set_enabled(true);
    static PREV_RAN: AtomicU32 = AtomicU32::new(0);

    let dir = std::env::temp_dir().join(format!("emr-trace-hook-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    // A "user" hook installed first: install_panic_hook must chain to it,
    // not replace it.
    let inherited = std::panic::take_hook();
    std::panic::set_hook(Box::new(move |info| {
        PREV_RAN.fetch_add(1, Ordering::SeqCst);
        inherited(info);
    }));

    assert!(trace::install_panic_hook(&dir), "first install");
    // Regression: a second install must refuse instead of stacking
    // another snapshot writer (which would write the dump twice and
    // re-chain the hook to itself).
    assert!(!trace::install_panic_hook(&dir), "second install must be a no-op");

    trace::event!("test.hook.before_panic", 41);
    let _ = std::panic::catch_unwind(|| panic!("trace test: intentional panic"));

    assert_eq!(PREV_RAN.load(Ordering::SeqCst), 1, "chained previous hook must run exactly once");
    let dump_path = trace::snapshot::crash_dump_path(&dir);
    let dump = trace::read_dump(&dump_path).expect("panic hook must leave a parseable dump");
    assert!(
        dump.events.iter().any(|e| dump.label(e) == "test.hook.before_panic" && e.arg == 41),
        "dump must contain events from before the panic"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// End-to-end crash path: a full `repro serve` run with the injected
/// worker panic (`--crash-test`) must exit cleanly (the poisoned
/// request errors instead of hanging) and leave a parseable crash dump
/// with real serving events in it.
#[test]
fn serve_crash_test_leaves_parseable_dump() {
    let dir = std::env::temp_dir().join(format!("emr-trace-e2e-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let out = std::process::Command::new(env!("CARGO_BIN_EXE_repro"))
        .args([
            "serve",
            "--backend",
            "synthetic",
            "--scheme",
            "stamp",
            "--shards",
            "2",
            "--frontend",
            "thread",
            "--clients",
            "2",
            "--requests",
            "50",
            "--trace",
            "on",
            "--crash-test",
        ])
        .arg("--trace-dir")
        .arg(&dir)
        .output()
        .expect("spawn repro serve");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        out.status.success(),
        "serve --crash-test must exit 0 (panic is confined to the worker)\n\
         stdout:\n{stdout}\nstderr:\n{stderr}"
    );
    assert!(
        stdout.contains("crash-test: worker panicked as injected"),
        "poison request must error promptly; stdout:\n{stdout}"
    );

    // The child's pid is unknown; there is exactly one dump in our dir.
    let dumps: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("trace-crash-") && n.ends_with(".bin"))
        })
        .collect();
    assert_eq!(dumps.len(), 1, "expected exactly one crash dump, found {dumps:?}");
    let dump = trace::read_dump(&dumps[0]).expect("crash dump must parse");
    assert!(!dump.events.is_empty(), "crash dump must not be empty");
    assert!(
        dump.events.iter().any(|e| dump.label(e) == "shard.submit"),
        "dump must contain the serving run's submit events"
    );

    // `repro trace view` decodes the same dump (text and JSON).
    let view = std::process::Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(["trace", "view"])
        .arg(&dumps[0])
        .output()
        .expect("spawn repro trace view");
    assert!(view.status.success(), "trace view must decode the dump");
    assert!(String::from_utf8_lossy(&view.stdout).contains("shard.submit"));
    let json = std::process::Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(["trace", "view"])
        .arg(&dumps[0])
        .arg("--json")
        .output()
        .expect("spawn repro trace view --json");
    assert!(json.status.success());
    assert!(String::from_utf8_lossy(&json.stdout).contains("\"events\""));

    let _ = std::fs::remove_dir_all(&dir);
}

/// The recorder pairs submit/complete events into real percentiles on a
/// live fleet (the E16/E17/E18 measurement path).
#[test]
fn latency_recorder_pairs_on_live_router() {
    use emr::coordinator::{Backend, Router, ServerConfig};
    use emr::reclaim::stamp::StampIt;
    let _g = lock();
    trace::set_enabled(true);

    let server = Router::<StampIt>::start(
        ServerConfig { workers: 1, capacity: 128, buckets: 32, ..ServerConfig::default() }
            .with_shards(2)
            .with_backend(Backend::synthetic()),
    )
    .unwrap();
    let rec = trace::LatencyRecorder::spawn(std::time::Duration::from_millis(1));
    for key in 0..300u32 {
        let _ = server.request(key % 64).unwrap();
    }
    let summary = rec.stop();
    server.shutdown();

    assert!(summary.pairs >= 250, "most submits must pair with completes: {summary:?}");
    assert!(summary.p50_ns > 0, "p50 must be a real latency: {summary:?}");
    assert!(
        summary.p50_ns <= summary.p99_ns && summary.p99_ns <= summary.p999_ns,
        "percentiles must be ordered: {summary:?}"
    );
    assert!(summary.max_ns >= summary.p999_ns, "{summary:?}");
}

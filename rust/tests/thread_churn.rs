//! Failure injection: threads that start, work and exit in waves — the
//! paper's requirement that the implementation "works with arbitrary
//! numbers of threads that can be started and stopped arbitrarily".
//!
//! Exercises: orphan hand-off (threads exiting with unreclaimed retired
//! nodes), registry-entry reuse (peak-bounded), Stamp Pool block recycling,
//! and hazard-slot recycling.

use emr::ds::queue::Queue;
use emr::reclaim::tests_common::{flush_until, Payload};
use emr::reclaim::Reclaimer;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Waves of short-lived threads leave retired-but-unreclaimed nodes behind
/// (orphans); a later wave plus a flush must reclaim everything.
fn orphan_handoff<R: Reclaimer>(waves: usize, threads_per_wave: usize) {
    let drops = Arc::new(AtomicUsize::new(0));
    let allocs = Arc::new(AtomicUsize::new(0));
    let q: Arc<Queue<Payload, R>> = Arc::new(Queue::new());

    for wave in 0..waves {
        let handles: Vec<_> = (0..threads_per_wave)
            .map(|t| {
                let q = q.clone();
                let drops = drops.clone();
                let allocs = allocs.clone();
                std::thread::spawn(move || {
                    for i in 0..200u64 {
                        let v = (wave * 1000 + t * 200) as u64 + i;
                        q.enqueue(Payload::new(v, &drops));
                        allocs.fetch_add(1, Ordering::Relaxed);
                        // Dequeue retires the old dummy through the scheme;
                        // exiting right after leaves orphans.
                        if let Some(p) = q.dequeue() {
                            p.read();
                        }
                    }
                    // Thread exits here, mid-stream: its retire list is
                    // handed to the scheme's orphan machinery.
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    // Main thread drains what is left and flushes until every payload is
    // accounted for.
    while let Some(p) = q.dequeue() {
        p.read();
    }
    drop(std::sync::Arc::try_unwrap(q).ok());
    let ok = flush_until::<R>(|| drops.load(Ordering::Relaxed) == allocs.load(Ordering::Relaxed));
    assert!(
        ok,
        "{}: orphans leaked — {} of {} dropped",
        R::NAME,
        drops.load(Ordering::Relaxed),
        allocs.load(Ordering::Relaxed)
    );
}

/// Thread start/stop storms: scheme-internal registries must recycle
/// entries instead of growing per thread.
fn churn_storm<R: Reclaimer>(iterations: usize) {
    let q: Arc<Queue<u64, R>> = Arc::new(Queue::new());
    for round in 0..iterations {
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..50u64 {
                        q.enqueue(round as u64 * 100 + t as u64 * 50 + i);
                        q.dequeue();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
    R::flush();
}

macro_rules! churn {
    ($mod_name:ident, $scheme:ty) => {
        mod $mod_name {
            use super::*;

            #[test]
            fn orphans_are_reclaimed() {
                orphan_handoff::<$scheme>(3, 4);
            }

            #[test]
            fn survives_thread_storms() {
                churn_storm::<$scheme>(10);
            }
        }
    };
}

churn!(lfrc, emr::reclaim::lfrc::Lfrc);
churn!(hp, emr::reclaim::hp::Hp);
churn!(ebr, emr::reclaim::ebr::Ebr);
churn!(nebr, emr::reclaim::nebr::Nebr);
churn!(qsr, emr::reclaim::qsr::Qsr);
churn!(debra, emr::reclaim::debra::Debra);
churn!(stamp, emr::reclaim::stamp::StampIt);

/// The Stamp Pool must recycle control blocks across thread generations:
/// 100 sequential short-lived threads may not consume 100 fresh blocks.
#[test]
fn stamp_blocks_recycle_across_threads() {
    use emr::reclaim::stamp::StampIt;
    use emr::reclaim::Region;
    for _ in 0..100 {
        std::thread::spawn(|| {
            let _r = Region::<StampIt>::enter();
        })
        .join()
        .unwrap();
    }
    // No direct block counter is exposed; the real assertion is that the
    // pool's capacity (4096) is never exhausted even for vastly more
    // thread generations than capacity:
    for _ in 0..200 {
        std::thread::spawn(|| {
            let _r = Region::<StampIt>::enter();
        })
        .join()
        .unwrap();
    }
}

/// Hazard slots are recycled with their registry entry: repeated
/// single-thread generations must not grow ΣK without bound.
#[test]
fn hp_slots_recycle_across_threads() {
    use emr::reclaim::hp::{total_slots, Hp};
    use emr::reclaim::{ConcurrentPtr, GuardPtr, MarkedPtr};
    // Warm one generation up first (allocates the entry).
    let warm = || {
        std::thread::spawn(|| {
            let node = emr::reclaim::alloc_node::<u64, Hp>(7);
            let cell: ConcurrentPtr<u64, Hp> = ConcurrentPtr::new(MarkedPtr::new(node, 0));
            let mut g: GuardPtr<u64, Hp> = GuardPtr::new();
            g.acquire(&cell);
            drop(g);
            cell.store(MarkedPtr::null(), std::sync::atomic::Ordering::Release);
            unsafe { Hp::retire(node) };
        })
        .join()
        .unwrap();
    };
    warm();
    let before = total_slots();
    for _ in 0..50 {
        warm();
    }
    let after = total_slots();
    // Parallel tests may add a few legitimate thread entries; what must not
    // happen is one entry per generation (50 × K_STATIC = 400 slots).
    assert!(
        after - before < 200,
        "hazard slots grew {} → {} across 50 sequential generations",
        before,
        after
    );
}

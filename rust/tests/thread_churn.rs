//! Failure injection: threads that start, work and exit in waves — the
//! paper's requirement that the implementation "works with arbitrary
//! numbers of threads that can be started and stopped arbitrarily".
//!
//! Exercises: orphan hand-off (threads exiting with unreclaimed retired
//! nodes), registry-entry reuse (peak-bounded) with fully reset recycled
//! state, Stamp Pool block recycling, and hazard-slot recycling — all on
//! owned domains, so the assertions are exact and unraced.

use emr::ds::queue::Queue;
use emr::reclaim::tests_common::{flush_until, Payload};
use emr::reclaim::{DomainRef, Reclaimer, Region};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Waves of short-lived threads leave retired-but-unreclaimed nodes behind
/// (orphans); a later flush must reclaim everything.
fn orphan_handoff<R: Reclaimer>(waves: usize, threads_per_wave: usize) {
    let domain = DomainRef::<R>::new_owned();
    let drops = Arc::new(AtomicUsize::new(0));
    let allocs = Arc::new(AtomicUsize::new(0));
    let q: Arc<Queue<Payload, R>> = Arc::new(Queue::new_in(domain.clone()));

    for wave in 0..waves {
        let handles: Vec<_> = (0..threads_per_wave)
            .map(|t| {
                let q = q.clone();
                let drops = drops.clone();
                let allocs = allocs.clone();
                std::thread::spawn(move || {
                    let h = q.domain().register();
                    for i in 0..200u64 {
                        let v = (wave * 1000 + t * 200) as u64 + i;
                        q.enqueue(&h, Payload::new(v, &drops));
                        allocs.fetch_add(1, Ordering::Relaxed);
                        // Dequeue retires the old dummy through the scheme;
                        // exiting right after leaves orphans.
                        if let Some(p) = q.dequeue(&h) {
                            p.read();
                        }
                    }
                    // Thread exits here, mid-stream: its handle drops and
                    // its retire list is handed to the domain's orphan
                    // machinery.
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    // Main thread drains what is left and flushes until every payload is
    // accounted for.
    let h = domain.register();
    while let Some(p) = q.dequeue(&h) {
        p.read();
    }
    drop(Arc::try_unwrap(q).ok());
    let ok = flush_until(&h, || drops.load(Ordering::Relaxed) == allocs.load(Ordering::Relaxed));
    assert!(
        ok,
        "{}: orphans leaked — {} of {} dropped",
        R::NAME,
        drops.load(Ordering::Relaxed),
        allocs.load(Ordering::Relaxed)
    );
}

/// Thread start/stop storms: domain-internal registries must recycle
/// entries instead of growing per thread.
fn churn_storm<R: Reclaimer>(iterations: usize) {
    let domain = DomainRef::<R>::new_owned();
    let q: Arc<Queue<u64, R>> = Arc::new(Queue::new_in(domain.clone()));
    for round in 0..iterations {
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let q = q.clone();
                std::thread::spawn(move || {
                    let h = q.domain().register();
                    for i in 0..50u64 {
                        q.enqueue(&h, round as u64 * 100 + t as u64 * 50 + i);
                        q.dequeue(&h);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
    let h = domain.register();
    h.flush();
}

macro_rules! churn {
    ($mod_name:ident, $scheme:ty) => {
        mod $mod_name {
            use super::*;

            #[test]
            fn orphans_are_reclaimed() {
                orphan_handoff::<$scheme>(3, 4);
            }

            #[test]
            fn survives_thread_storms() {
                churn_storm::<$scheme>(10);
            }
        }
    };
}

churn!(lfrc, emr::reclaim::lfrc::Lfrc);
churn!(hp, emr::reclaim::hp::Hp);
churn!(ebr, emr::reclaim::ebr::Ebr);
churn!(nebr, emr::reclaim::nebr::Nebr);
churn!(qsr, emr::reclaim::qsr::Qsr);
churn!(debra, emr::reclaim::debra::Debra);
churn!(stamp, emr::reclaim::stamp::StampIt);
churn!(hyaline, emr::reclaim::hyaline::Hyaline);

/// The Stamp Pool must recycle control blocks across handle generations:
/// vastly more sequential registrations than the pool's capacity (4096)
/// may not exhaust it.
#[test]
fn stamp_blocks_recycle_across_threads() {
    use emr::reclaim::stamp::StampIt;
    let domain = DomainRef::<StampIt>::new_owned();
    // 3× the pool capacity of sequential handle generations: if unregister
    // stopped returning blocks to the free-list, `alloc_block` would assert
    // "stamp pool exhausted" partway through this loop.
    for _ in 0..3 * 4096 {
        let h = domain.register();
        let _r = Region::enter(&h);
    }
    // And across real thread generations (exercises handle drop at thread
    // exit rather than in-scope drop).
    for _ in 0..32 {
        let domain = domain.clone();
        std::thread::spawn(move || {
            let h = domain.register();
            let _r = Region::enter(&h);
        })
        .join()
        .unwrap();
    }
}

/// Hazard slots are recycled with their registry entry: repeated
/// single-thread generations must not grow ΣK at all on an owned domain.
#[test]
fn hp_slots_recycle_across_threads() {
    use emr::reclaim::hp::Hp;
    let domain = DomainRef::<Hp>::new_owned();
    // Warm one generation up first (allocates the entry).
    let warm = |domain: &DomainRef<Hp>| {
        let domain = domain.clone();
        std::thread::spawn(move || {
            use emr::reclaim::{Atomic, Guard, MarkedPtr, Owned};
            let h = domain.register();
            let cell: Atomic<u64, Hp> = Atomic::new(Owned::new(7));
            let node = cell.load(std::sync::atomic::Ordering::Relaxed);
            let mut g: Guard<u64, Hp> = h.guard();
            assert!(g.protect(&cell).is_some());
            drop(g);
            cell.store(MarkedPtr::null(), std::sync::atomic::Ordering::Release);
            // SAFETY: unlinked above; retired exactly once, in-domain.
            unsafe { h.retire(node.get()) };
        })
        .join()
        .unwrap();
    };
    warm(&domain);
    let before = domain.domain().state().total_slots();
    for _ in 0..50 {
        warm(&domain);
    }
    let after = domain.domain().state().total_slots();
    // Owned domain ⇒ nobody else registers: sequential generations must
    // reuse the single recycled entry exactly (one entry per peak thread,
    // not one per generation).
    assert_eq!(
        after, before,
        "hazard slots grew {before} → {after} across 50 sequential generations"
    );
}

/// Recycled registry entries must come back with fully reset epoch state:
/// a stale announcement from a dead thread would block the epoch forever.
#[test]
fn recycled_entries_have_reset_epoch_state() {
    use emr::reclaim::qsr::Qsr;
    let domain = DomainRef::<Qsr>::new_owned();
    let drops = Arc::new(AtomicUsize::new(0));

    // Generation 1: register, retire a node, exit without ever passing
    // another quiescent state — the node is orphaned, and the entry is
    // released holding a stale (old-epoch) announcement value.
    {
        let domain = domain.clone();
        let drops = drops.clone();
        std::thread::spawn(move || {
            let h = domain.register();
            // Safe retire path: the Owned node is trivially unlinked.
            h.retire_owned(emr::reclaim::Owned::<Payload, Qsr>::new(Payload::new(1, &drops)));
        })
        .join()
        .unwrap();
    }

    // Generation 2: recycles the entry (peak concurrency is 1). If the
    // recycled entry's announcement were not reset, QSR's epoch could
    // never advance past the dead thread's stale value and the orphan
    // would leak.
    {
        let domain = domain.clone();
        std::thread::spawn(move || {
            let h = domain.register();
            for _ in 0..4 {
                let _r = Region::enter(&h);
            }
        })
        .join()
        .unwrap();
    }

    let h = domain.register();
    let ok = flush_until(&h, || drops.load(Ordering::Relaxed) == 1);
    assert!(ok, "stale recycled epoch state blocked reclamation");
}

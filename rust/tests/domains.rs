//! Reclamation-domain isolation under concurrency: independent domains of
//! the same scheme must never observe each other's retired nodes, even
//! while both churn from multiple threads at once. Plus the TLS
//! handle-cache eviction policy (dead owned domains must not stay pinned
//! by long-lived threads).

use emr::ds::queue::Queue;
use emr::reclaim::tests_common::{flush_until, Payload};
use emr::reclaim::{Atomic, DomainRef, Guard, MarkedPtr, Owned, Reclaimer};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Two domains churn concurrently; a guard held in domain A pins its node
/// for the whole run regardless of how hard domain B reclaims.
fn concurrent_domains_do_not_cross_reclaim<R: Reclaimer>() {
    let domain_a = DomainRef::<R>::new_owned();
    let domain_b = DomainRef::<R>::new_owned();
    let drops_a = Arc::new(AtomicUsize::new(0));

    // Domain A, main thread: guard a retired node.
    let ha = domain_a.register();
    let cell_a: Atomic<Payload, R> = Atomic::new(Owned::new(Payload::new(0xAA, &drops_a)));
    let node_a = cell_a.load(Ordering::Relaxed);
    let mut guard_a: Guard<Payload, R> = ha.guard();
    assert!(guard_a.protect(&cell_a).is_some());
    cell_a.store(MarkedPtr::null(), Ordering::Release);
    // SAFETY: unlinked; retired once, into the guarding domain.
    unsafe { ha.retire(node_a.get()) };

    // Domain B: 4 threads churn a queue (steady retire stream) and flush
    // aggressively the whole time.
    let q: Arc<Queue<u64, R>> = Arc::new(Queue::new_in(domain_b.clone()));
    let threads: Vec<_> = (0..4)
        .map(|t| {
            let q = q.clone();
            std::thread::spawn(move || {
                let h = q.domain().register();
                for i in 0..2000u64 {
                    q.enqueue(&h, t * 10_000 + i);
                    q.dequeue(&h);
                    if i % 64 == 0 {
                        h.flush();
                    }
                }
                h.flush();
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }

    // Everything domain B did must leave domain A's guarded node alone.
    assert_eq!(drops_a.load(Ordering::Relaxed), 0, "{}: cross-domain reclamation", R::NAME);
    assert_eq!(guard_a.shared().expect("still guarded").read(), 0xAA);

    drop(guard_a);
    flush_until(&ha, || drops_a.load(Ordering::Relaxed) == 1);
    assert_eq!(drops_a.load(Ordering::Relaxed), 1, "{}: leak after guard drop", R::NAME);
}

/// One shared owned domain across threads: handles registered from many
/// threads cooperate exactly like the global domain does.
fn shared_owned_domain_reclaims<R: Reclaimer>() {
    let domain = DomainRef::<R>::new_owned();
    let drops = Arc::new(AtomicUsize::new(0));
    let allocs = Arc::new(AtomicUsize::new(0));
    let threads: Vec<_> = (0..4)
        .map(|_| {
            let domain = domain.clone();
            let drops = drops.clone();
            let allocs = allocs.clone();
            std::thread::spawn(move || {
                let h = domain.register();
                for i in 0..500u64 {
                    // Safe retire path: Owned nodes are trivially unlinked.
                    h.retire_owned(Owned::<Payload, R>::new(Payload::new(i, &drops)));
                    allocs.fetch_add(1, Ordering::Relaxed);
                    if i % 50 == 0 {
                        h.flush();
                    }
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    let h = domain.register();
    let ok = flush_until(&h, || drops.load(Ordering::Relaxed) == allocs.load(Ordering::Relaxed));
    assert!(
        ok,
        "{}: shared domain leaked — {}/{}",
        R::NAME,
        drops.load(Ordering::Relaxed),
        allocs.load(Ordering::Relaxed)
    );
}

/// The TLS handle cache must evict cached handles whose owned domain is
/// otherwise dead, draining whatever the dead domain still parked.
fn handle_cache_evicts_dead_domain<R: Reclaimer>() {
    let drops = Arc::new(AtomicUsize::new(0));
    {
        let domain = DomainRef::<R>::new_owned();
        // Resolve (and cache) this thread's handle, and park a retired
        // node in its local retire list without any reclamation trigger.
        domain.with_handle(|h| {
            h.retire_owned(Owned::<Payload, R>::new(Payload::new(1, &drops)));
        });
        // `domain` drops here: the TLS cache entry is now the sole owner.
    }
    // Any later cached-handle resolution on this thread sweeps the cache:
    // the dead domain's handle unregisters and the domain drains.
    let other = DomainRef::<R>::new_owned();
    other.with_handle(|_| ());
    assert_eq!(
        drops.load(Ordering::Relaxed),
        1,
        "{}: evicted domain must drain its parked nodes",
        R::NAME
    );
}

/// Multi-thread pinning: two long-lived threads cache handles to the same
/// owned domain; once every external reference is gone, sweeps on the
/// (still running) threads must drain it — cache entries on *other*
/// threads must not count as keeping the domain alive.
fn handle_cache_evicts_across_threads<R: Reclaimer>() {
    use std::sync::Barrier;
    let drops = Arc::new(AtomicUsize::new(0));
    let domain = DomainRef::<R>::new_owned();
    let gate = Arc::new(Barrier::new(3));
    let spawn_worker = |domain: DomainRef<R>, drops: Arc<AtomicUsize>, gate: Arc<Barrier>| {
        std::thread::spawn(move || {
            let sweep = || {
                // Resolving any domain on this thread sweeps its cache.
                let other = DomainRef::<R>::new_owned();
                other.with_handle(|_| ());
            };
            domain.with_handle(|h| {
                h.retire_owned(Owned::<Payload, R>::new(Payload::new(1, &drops)));
            });
            drop(domain); // this thread now holds the domain only via TLS
            gate.wait(); // A: caches populated, worker externals dropped
            gate.wait(); // B: main dropped its reference too
            sweep();
            gate.wait(); // C: first sweep round done (may defer on races)
            sweep();
            gate.wait(); // D: second round done — eviction has cascaded
            gate.wait(); // E: main asserted; thread may exit
        })
    };
    let t1 = spawn_worker(domain.clone(), drops.clone(), gate.clone());
    let t2 = spawn_worker(domain.clone(), drops.clone(), gate.clone());
    gate.wait(); // A
    drop(domain);
    gate.wait(); // B
    gate.wait(); // C
    gate.wait(); // D
    assert_eq!(
        drops.load(Ordering::Relaxed),
        2,
        "{}: cache pins on live threads must not leak a dead domain",
        R::NAME
    );
    gate.wait(); // E
    t1.join().unwrap();
    t2.join().unwrap();
}

/// Eviction must never fire while any outside `DomainRef` is still alive:
/// a cached handle stays cached across repeated resolutions.
fn handle_cache_keeps_live_domains<R: Reclaimer>() {
    let drops = Arc::new(AtomicUsize::new(0));
    let domain = DomainRef::<R>::new_owned();
    domain.with_handle(|h| {
        h.retire_owned(Owned::<Payload, R>::new(Payload::new(7, &drops)));
    });
    // Resolutions for *other* domains sweep the cache; this domain is
    // still externally owned, so its entry (and parked node) must stay.
    for _ in 0..3 {
        let other = DomainRef::<R>::new_owned();
        other.with_handle(|_| ());
    }
    // The node may only have been reclaimed by the scheme's own normal
    // operation, never by an eviction-triggered drain of a live domain:
    // the domain must still function through the cached handle.
    domain.with_handle(|h| h.flush());
    let h = domain.register();
    flush_until(&h, || drops.load(Ordering::Relaxed) == 1);
    assert_eq!(drops.load(Ordering::Relaxed), 1, "{}: parked node lost", R::NAME);
}

/// Per-domain unreclaimed counters (the sharded coordinator's per-shard
/// robustness metric): a retire in domain A moves only A's counter, B's
/// stays at 0 — "two shards never share retire lists" made observable —
/// and the counter returns to 0 once the node is reclaimed.
fn unreclaimed_is_per_domain<R: Reclaimer>() {
    let domain_a = DomainRef::<R>::new_owned();
    let domain_b = DomainRef::<R>::new_owned();
    assert_eq!(domain_a.domain().unreclaimed(), 0);
    assert_eq!(domain_b.domain().unreclaimed(), 0);

    let drops = Arc::new(AtomicUsize::new(0));
    let ha = domain_a.register();
    let _hb = domain_b.register(); // B is live, just never retires
    let cell: Atomic<Payload, R> = Atomic::new(Owned::new(Payload::new(1, &drops)));
    let node = cell.load(Ordering::Relaxed);
    let mut guard: Guard<Payload, R> = ha.guard();
    assert!(guard.protect(&cell).is_some());
    cell.store(MarkedPtr::null(), Ordering::Release);
    // SAFETY: unlinked; retired once, into the guarding domain.
    unsafe { ha.retire(node.get()) };

    // The guard pins the node (Proposition 1), so it is retired-not-
    // reclaimed: exactly A's counter shows it.
    assert_eq!(domain_a.domain().unreclaimed(), 1, "{}: retire must count in A", R::NAME);
    assert_eq!(domain_b.domain().unreclaimed(), 0, "{}: B must be unaffected", R::NAME);

    drop(guard);
    flush_until(&ha, || drops.load(Ordering::Relaxed) == 1);
    assert_eq!(domain_a.domain().unreclaimed(), 0, "{}: reclaim must un-count", R::NAME);
    assert_eq!(domain_b.domain().unreclaimed(), 0);
}

macro_rules! domain_tests {
    ($mod_name:ident, $scheme:ty) => {
        mod $mod_name {
            use super::*;

            #[test]
            fn concurrent_isolation() {
                concurrent_domains_do_not_cross_reclaim::<$scheme>();
            }

            #[test]
            fn unreclaimed_counter_is_per_domain() {
                unreclaimed_is_per_domain::<$scheme>();
            }

            #[test]
            fn shared_owned_domain() {
                shared_owned_domain_reclaims::<$scheme>();
            }

            #[test]
            fn cache_evicts_dead_domain() {
                handle_cache_evicts_dead_domain::<$scheme>();
            }

            #[test]
            fn cache_evicts_across_threads() {
                handle_cache_evicts_across_threads::<$scheme>();
            }

            #[test]
            fn cache_keeps_live_domains() {
                handle_cache_keeps_live_domains::<$scheme>();
            }
        }
    };
}

domain_tests!(lfrc, emr::reclaim::lfrc::Lfrc);
domain_tests!(hp, emr::reclaim::hp::Hp);
domain_tests!(ebr, emr::reclaim::ebr::Ebr);
domain_tests!(nebr, emr::reclaim::nebr::Nebr);
domain_tests!(qsr, emr::reclaim::qsr::Qsr);
domain_tests!(debra, emr::reclaim::debra::Debra);
domain_tests!(stamp, emr::reclaim::stamp::StampIt);
domain_tests!(hyaline, emr::reclaim::hyaline::Hyaline);

//! Reclamation-domain isolation under concurrency: independent domains of
//! the same scheme must never observe each other's retired nodes, even
//! while both churn from multiple threads at once.

use emr::ds::queue::Queue;
use emr::reclaim::tests_common::{flush_until, Payload};
use emr::reclaim::{ConcurrentPtr, DomainRef, MarkedPtr, Reclaimer};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Two domains churn concurrently; a guard held in domain A pins its node
/// for the whole run regardless of how hard domain B reclaims.
fn concurrent_domains_do_not_cross_reclaim<R: Reclaimer>() {
    let domain_a = DomainRef::<R>::new_owned();
    let domain_b = DomainRef::<R>::new_owned();
    let drops_a = Arc::new(AtomicUsize::new(0));

    // Domain A, main thread: guard a retired node.
    let ha = domain_a.register();
    let node_a = emr::reclaim::alloc_node::<Payload, R>(Payload::new(0xAA, &drops_a));
    let cell_a: ConcurrentPtr<Payload, R> = ConcurrentPtr::new(MarkedPtr::new(node_a, 0));
    let mut guard_a = ha.guard();
    guard_a.acquire(&cell_a);
    cell_a.store(MarkedPtr::null(), Ordering::Release);
    // SAFETY: unlinked; retired once, into the guarding domain.
    unsafe { ha.retire(node_a) };

    // Domain B: 4 threads churn a queue (steady retire stream) and flush
    // aggressively the whole time.
    let q: Arc<Queue<u64, R>> = Arc::new(Queue::new_in(domain_b.clone()));
    let threads: Vec<_> = (0..4)
        .map(|t| {
            let q = q.clone();
            std::thread::spawn(move || {
                let h = q.domain().register();
                for i in 0..2000u64 {
                    q.enqueue_with(&h, t * 10_000 + i);
                    q.dequeue_with(&h);
                    if i % 64 == 0 {
                        h.flush();
                    }
                }
                h.flush();
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }

    // Everything domain B did must leave domain A's guarded node alone.
    assert_eq!(drops_a.load(Ordering::Relaxed), 0, "{}: cross-domain reclamation", R::NAME);
    assert_eq!(guard_a.as_ref().unwrap().read(), 0xAA);

    drop(guard_a);
    flush_until(&ha, || drops_a.load(Ordering::Relaxed) == 1);
    assert_eq!(drops_a.load(Ordering::Relaxed), 1, "{}: leak after guard drop", R::NAME);
}

/// One shared owned domain across threads: handles registered from many
/// threads cooperate exactly like the global domain does.
fn shared_owned_domain_reclaims<R: Reclaimer>() {
    let domain = DomainRef::<R>::new_owned();
    let drops = Arc::new(AtomicUsize::new(0));
    let allocs = Arc::new(AtomicUsize::new(0));
    let threads: Vec<_> = (0..4)
        .map(|_| {
            let domain = domain.clone();
            let drops = drops.clone();
            let allocs = allocs.clone();
            std::thread::spawn(move || {
                let h = domain.register();
                for i in 0..500u64 {
                    let node = emr::reclaim::alloc_node::<Payload, R>(Payload::new(i, &drops));
                    allocs.fetch_add(1, Ordering::Relaxed);
                    // SAFETY: never published.
                    unsafe { h.retire(node) };
                    if i % 50 == 0 {
                        h.flush();
                    }
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    let h = domain.register();
    let ok = flush_until(&h, || drops.load(Ordering::Relaxed) == allocs.load(Ordering::Relaxed));
    assert!(
        ok,
        "{}: shared domain leaked — {}/{}",
        R::NAME,
        drops.load(Ordering::Relaxed),
        allocs.load(Ordering::Relaxed)
    );
}

macro_rules! domain_tests {
    ($mod_name:ident, $scheme:ty) => {
        mod $mod_name {
            use super::*;

            #[test]
            fn concurrent_isolation() {
                concurrent_domains_do_not_cross_reclaim::<$scheme>();
            }

            #[test]
            fn shared_owned_domain() {
                shared_owned_domain_reclaims::<$scheme>();
            }
        }
    };
}

domain_tests!(lfrc, emr::reclaim::lfrc::Lfrc);
domain_tests!(hp, emr::reclaim::hp::Hp);
domain_tests!(ebr, emr::reclaim::ebr::Ebr);
domain_tests!(nebr, emr::reclaim::nebr::Nebr);
domain_tests!(qsr, emr::reclaim::qsr::Qsr);
domain_tests!(debra, emr::reclaim::debra::Debra);
domain_tests!(stamp, emr::reclaim::stamp::StampIt);

//! End-to-end tests of the TCP serving front (DESIGN.md §8):
//! `NetServer` + reactor + wire protocol + completion bridge, all over
//! real loopback sockets on the synthetic backend — artifact-free.
//!
//! The invariants under test are the ISSUE's acceptance criteria: requests
//! round-trip byte-correct under every scheme, a thousand concurrent
//! connections are served with zero errors and every gauge drains to zero
//! at shutdown, and a client that disconnects mid-flight leaves no leaked
//! completion slot and no wedged worker behind.

use emr::bench_fw::workload::compute_payload;
use emr::coordinator::frontend::net::client::{storm, NetClient, StormConfig};
use emr::coordinator::frontend::net::proto::Status;
use emr::coordinator::frontend::net::{NetConfig, NetServer};
use emr::coordinator::{Backend, Router, ServerConfig};
use emr::reclaim::ebr::Ebr;
use emr::reclaim::hp::Hp;
use emr::reclaim::stamp::StampIt;
use emr::reclaim::Reclaimer;
use std::io::ErrorKind;
use std::time::{Duration, Instant};

fn synthetic_cfg() -> ServerConfig {
    ServerConfig {
        workers: 2,
        capacity: 128,
        buckets: 32,
        ..ServerConfig::default()
    }
    .with_backend(Backend::synthetic())
}

/// Small bridge pool for tests (the default 8 is the bench budget).
fn net_cfg() -> NetConfig {
    NetConfig { exec_threads: 2, ..NetConfig::default() }
}

/// Wait (bounded) for `f` to turn true; returns its final value.
fn wait_until(timeout: Duration, mut f: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + timeout;
    while Instant::now() < deadline {
        if f() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    f()
}

/// A request served over the wire must carry the exact synthetic payload —
/// miss first, then a cache hit — under each scheme.
fn wire_roundtrip<R: Reclaimer>() {
    let server = Router::<R>::start(synthetic_cfg()).unwrap();
    let mut net = NetServer::start(server.clone(), net_cfg()).unwrap();
    let mut client = NetClient::connect(net.local_addr()).unwrap();
    client.set_timeout(Some(Duration::from_secs(30))).unwrap();

    let miss = client.request(7).expect("first request");
    assert_eq!(miss.status, Status::Ok);
    assert!(!miss.hit, "{}: first request must be computed", R::NAME);
    assert_eq!(miss.data.expect("payload")[..], compute_payload(7)[..]);

    let hit = client.request(7).expect("second request");
    assert_eq!(hit.status, Status::Ok);
    assert!(hit.hit, "{}: second request must be served from cache", R::NAME);
    assert_eq!(hit.data.expect("payload")[..], compute_payload(7)[..]);

    let m = server.metrics();
    assert_eq!(m.requests, 2);
    assert_eq!(m.hits, 1);
    net.shutdown();
    server.shutdown();
}

#[test]
fn wire_roundtrip_stamp() {
    wire_roundtrip::<StampIt>();
}

#[test]
fn wire_roundtrip_hp() {
    wire_roundtrip::<Hp>();
}

#[test]
fn wire_roundtrip_ebr() {
    wire_roundtrip::<Ebr>();
}

#[test]
fn thousand_connections_drain_to_zero_at_shutdown() {
    // 1000 real sockets against one reactor thread and a 2-thread bridge
    // pool: every request answered, no protocol errors, and — the leak
    // detector — both the `in_flight` completion gauge and the
    // `active_connections` gauge read exactly zero after shutdown.
    let server = Router::<StampIt>::start(synthetic_cfg().with_shards(4)).unwrap();
    let mut net = NetServer::start(server.clone(), net_cfg()).unwrap();
    let report = storm(
        net.local_addr(),
        &StormConfig {
            conns: 1000,
            requests_per_conn: 5,
            key_space: 2_000,
            hot_pct: 80,
            seed: 0xE18,
            ..StormConfig::default()
        },
    );
    assert_eq!(report.errors, 0, "no request may be dropped");
    assert_eq!(report.received, 1000 * 5);
    let m = server.metrics();
    assert_eq!(m.requests, 1000 * 5);
    assert_eq!(m.hits + m.misses, 1000 * 5);
    assert!(
        wait_until(Duration::from_secs(10), || server.metrics().in_flight == 0),
        "in_flight must drain once every response is routed: {}",
        server.metrics().in_flight
    );
    // The storm dropped its sockets; the reactor notices each EOF.
    assert!(
        wait_until(Duration::from_secs(10), || net.metrics().active == 0),
        "active_connections must drain after the clients hang up: {}",
        net.metrics().active
    );
    let stats = net.metrics();
    assert_eq!(stats.protocol_errors, 0);
    assert!(stats.accepted >= 1000);
    assert_eq!(stats.accepted, stats.closed, "every accepted connection must be closed");
    net.shutdown();
    assert_eq!(net.metrics().active, 0);
    server.shutdown();
    assert_eq!(server.metrics().queue_depth, 0, "shutdown must drain the queues");
}

#[test]
fn midflight_disconnect_leaks_no_slots_and_wedges_no_worker() {
    // Clients fire pipelined requests and vanish before reading a single
    // response byte. The submissions still fulfil their completion slots
    // (the reactor drops the orphan frames), so `in_flight` drains to
    // exactly zero and a fresh connection is served normally.
    let server = Router::<StampIt>::start(synthetic_cfg().with_shards(2)).unwrap();
    let mut net = NetServer::start(server.clone(), net_cfg()).unwrap();
    for round in 0..8u32 {
        let mut doomed: Vec<NetClient> = (0..16)
            .map(|_| NetClient::connect(net.local_addr()).unwrap())
            .collect();
        for (i, c) in doomed.iter_mut().enumerate() {
            for k in 0..4u32 {
                c.send(round * 64 + i as u32 * 4 + k).unwrap();
            }
        }
        drop(doomed); // FIN races the responses: some frames orphan
    }
    assert!(
        wait_until(Duration::from_secs(30), || server.metrics().in_flight == 0),
        "abandoned requests leaked in_flight slots: {}",
        server.metrics().in_flight
    );
    assert!(
        wait_until(Duration::from_secs(10), || net.metrics().active == 0),
        "dead connections must be reaped: {}",
        net.metrics().active
    );
    // Workers and reactor are not wedged: a fresh client round-trips.
    let mut probe = NetClient::connect(net.local_addr()).unwrap();
    probe.set_timeout(Some(Duration::from_secs(30))).unwrap();
    let r = probe.request(3).expect("post-churn request");
    assert_eq!(r.data.expect("payload")[..], compute_payload(3)[..]);
    net.shutdown();
    server.shutdown();
}

#[test]
fn zero_length_key_gets_bad_request_and_the_conn_survives() {
    let server = Router::<Ebr>::start(synthetic_cfg()).unwrap();
    let mut net = NetServer::start(server.clone(), net_cfg()).unwrap();
    let mut client = NetClient::connect(net.local_addr()).unwrap();
    client.set_timeout(Some(Duration::from_secs(30))).unwrap();

    // Hand-crafted frame: length prefix 8, request id, no key bytes.
    let mut raw = Vec::new();
    raw.extend_from_slice(&8u32.to_le_bytes());
    raw.extend_from_slice(&0xDEADu64.to_le_bytes());
    client.send_raw(&raw).unwrap();
    let resp = client.recv().expect("BadRequest must be answered");
    assert_eq!(resp.id, 0xDEAD);
    assert_eq!(resp.status, Status::BadRequest);
    assert!(resp.data.is_none());

    // Answerable, not fatal: the same connection still serves requests.
    let ok = client.request(5).expect("request after BadRequest");
    assert_eq!(ok.data.expect("payload")[..], compute_payload(5)[..]);
    assert!(net.metrics().protocol_errors >= 1);
    net.shutdown();
    server.shutdown();
}

#[test]
fn malformed_frames_close_the_conn_but_not_the_server() {
    let server = Router::<Hp>::start(synthetic_cfg()).unwrap();
    let mut net = NetServer::start(server.clone(), net_cfg()).unwrap();

    // Oversized: a length prefix beyond the request bound is rejected
    // before any buffering; the connection is closed.
    let mut a = NetClient::connect(net.local_addr()).unwrap();
    a.set_timeout(Some(Duration::from_secs(10))).unwrap();
    a.send_raw(&u32::MAX.to_le_bytes()).unwrap();
    let err = a.recv().expect_err("oversized frame must kill the connection");
    assert_eq!(err.kind(), ErrorKind::UnexpectedEof, "{err}");

    // Truncated: a body too short to carry a request id cannot be
    // answered; fatal as well.
    let mut b = NetClient::connect(net.local_addr()).unwrap();
    b.set_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut raw = Vec::new();
    raw.extend_from_slice(&4u32.to_le_bytes());
    raw.extend_from_slice(&[1, 2, 3, 4]);
    b.send_raw(&raw).unwrap();
    let err = b.recv().expect_err("truncated frame must kill the connection");
    assert_eq!(err.kind(), ErrorKind::UnexpectedEof, "{err}");

    assert!(
        wait_until(Duration::from_secs(5), || net.metrics().protocol_errors >= 2),
        "both violations must be counted: {}",
        net.metrics().protocol_errors
    );
    // The process survives: a fresh connection is served normally.
    let mut c = NetClient::connect(net.local_addr()).unwrap();
    c.set_timeout(Some(Duration::from_secs(30))).unwrap();
    let r = c.request(9).expect("request after protocol violations");
    assert_eq!(r.data.expect("payload")[..], compute_payload(9)[..]);
    net.shutdown();
    server.shutdown();
}

#[test]
fn idle_connections_are_evicted() {
    let server = Router::<StampIt>::start(synthetic_cfg()).unwrap();
    let mut net = NetServer::start(
        server.clone(),
        NetConfig {
            exec_threads: 2,
            idle_timeout: Duration::from_millis(100),
            ..NetConfig::default()
        },
    )
    .unwrap();
    let mut idlers: Vec<NetClient> = (0..3)
        .map(|_| NetClient::connect(net.local_addr()).unwrap())
        .collect();
    // The reactor must notice them before it can evict them.
    assert!(wait_until(Duration::from_secs(5), || net.metrics().accepted >= 3));
    assert!(
        wait_until(Duration::from_secs(10), || net.metrics().idle_evicted >= 3),
        "idle connections must be evicted: {:?}",
        net.metrics()
    );
    assert!(wait_until(Duration::from_secs(5), || net.metrics().active == 0));
    // The eviction is visible client-side as EOF.
    for c in &mut idlers {
        c.set_timeout(Some(Duration::from_secs(10))).unwrap();
        assert_eq!(c.recv().expect_err("evicted").kind(), ErrorKind::UnexpectedEof);
    }
    net.shutdown();
    server.shutdown();
}

#[test]
fn metrics_rollup_carries_listener_counters() {
    // The net_* block rides Router::metrics the way magazine counters do:
    // set once process-wide, visible in the Display line.
    let server = Router::<StampIt>::start(synthetic_cfg()).unwrap();
    let mut net = NetServer::start(server.clone(), net_cfg()).unwrap();
    let mut client = NetClient::connect(net.local_addr()).unwrap();
    client.set_timeout(Some(Duration::from_secs(30))).unwrap();
    client.request(1).unwrap();
    let m = server.metrics();
    assert!(m.net_accepted >= 1, "rollup must see the listener: {m}");
    assert!(m.net_bytes_in > 0 && m.net_bytes_out > 0);
    assert!(format!("{m}").contains("net_accepted="));
    net.shutdown();
    server.shutdown();
}

//! Compile-fail suite for the lifetime-branded facade: proves at the type
//! level that `Shared<'g, T>` cannot escape the `Guard` that protects it,
//! that a guard cannot outlive its `LocalHandle`, and that a `Shared`
//! cannot escape the scope of its handle's domain resolution.
//!
//! The crate is deliberately std-only (no `trybuild`), so this is a
//! minimal harness: each fixture is compiled with `rustc --emit=metadata`
//! against the already-built `libemr` rlib next to the test binary, and
//! must fail with a borrow-check/lifetime error (and must NOT fail with a
//! resolution error, which would mean the harness is wired wrong). A
//! positive control proves the wiring compiles valid facade code.

use std::path::PathBuf;
use std::process::Command;

fn rustc() -> String {
    std::env::var("RUSTC").unwrap_or_else(|_| "rustc".into())
}

/// The deps dir the test binary was linked from (contains libemr-*.rlib).
fn deps_dir() -> PathBuf {
    std::env::current_exe()
        .expect("current_exe")
        .parent()
        .expect("deps dir")
        .to_path_buf()
}

/// Newest libemr rlib in the deps dir.
fn emr_rlib() -> PathBuf {
    let mut best: Option<(std::time::SystemTime, PathBuf)> = None;
    let dir = deps_dir();
    for entry in std::fs::read_dir(&dir).expect("read deps dir").flatten() {
        let name = entry.file_name().to_string_lossy().into_owned();
        if name.starts_with("libemr-") && name.ends_with(".rlib") {
            if let Ok(mtime) = entry.metadata().and_then(|m| m.modified()) {
                let newer = match &best {
                    None => true,
                    Some((t, _)) => mtime > *t,
                };
                if newer {
                    best = Some((mtime, entry.path()));
                }
            }
        }
    }
    best.map(|(_, p)| p).unwrap_or_else(|| panic!("no libemr-*.rlib in {dir:?}"))
}

/// Compile `source` as a lib crate; returns (succeeded, stderr).
fn compile(name: &str, source: &str) -> (bool, String) {
    let tmp = PathBuf::from(env!("CARGO_TARGET_TMPDIR"));
    std::fs::create_dir_all(&tmp).expect("tmpdir");
    let src = tmp.join(format!("{name}.rs"));
    std::fs::write(&src, source).expect("write fixture");
    let out = Command::new(rustc())
        .arg("--edition=2021")
        .arg("--crate-type=lib")
        .arg("--emit=metadata")
        .arg("-o")
        .arg(tmp.join(format!("lib{name}.rmeta")))
        .arg("--extern")
        .arg(format!("emr={}", emr_rlib().display()))
        .arg("-L")
        .arg(format!("dependency={}", deps_dir().display()))
        .arg(&src)
        .output()
        .expect("spawn rustc");
    (out.status.success(), String::from_utf8_lossy(&out.stderr).into_owned())
}

/// The fixture must fail to compile, with one of `expect_any` in stderr,
/// and with no resolution errors (those would mean a broken harness, not
/// a proven property).
fn assert_compile_fail(name: &str, source: &str, expect_any: &[&str]) {
    let (ok, stderr) = compile(name, source);
    assert!(!ok, "{name}: expected a borrow/lifetime error, but the fixture compiled");
    for wrong in ["E0432", "E0433", "E0463", "E0460", "E0461", "E0514"] {
        assert!(
            !stderr.contains(wrong),
            "{name}: failed for the wrong reason ({wrong} — harness wiring):\n{stderr}"
        );
    }
    assert!(
        expect_any.iter().any(|pat| stderr.contains(pat)),
        "{name}: expected one of {expect_any:?} in rustc stderr:\n{stderr}"
    );
}

const PRELUDE: &str = "use emr::reclaim::{ebr::Ebr, Atomic, Guard, LocalHandle};\n";

#[test]
fn positive_control_compiles() {
    let src = format!(
        "{PRELUDE}
use emr::reclaim::MarkedPtr;

pub fn reuse_shield(h: &LocalHandle<Ebr>, cell: &Atomic<u64, Ebr>) -> Option<u64> {{
    let mut g: Guard<u64, Ebr> = Guard::new(h);
    let v = g.protect(cell).map(|s| *s.get());
    g.reset(); // fine: the Shared above is already dead
    let mut walk: Guard<u64, Ebr> = Guard::new(h);
    std::mem::swap(&mut g, &mut walk); // shields move freely when unborrowed
    let _ = walk.try_protect(cell, MarkedPtr::null());
    v
}}
"
    );
    let (ok, stderr) = compile("cf_positive_control", &src);
    assert!(ok, "positive control must compile (harness wiring broken?):\n{stderr}");
}

#[test]
fn shared_cannot_be_returned_past_its_guard() {
    let src = format!(
        "{PRELUDE}
pub fn escape<'h>(h: &'h LocalHandle<Ebr>, cell: &Atomic<u64, Ebr>) -> &'h u64 {{
    let mut g: Guard<'h, u64, Ebr> = Guard::new(h);
    let s = g.protect(cell).unwrap();
    s.get() // Shared is branded by the borrow of `g`, a local
}}
"
    );
    assert_compile_fail("cf_escape_guard", &src, &["E0515", "E0597", "E0505"]);
}

#[test]
fn shared_dies_on_guard_reset() {
    let src = format!(
        "{PRELUDE}
pub fn use_after_reset(h: &LocalHandle<Ebr>, cell: &Atomic<u64, Ebr>) -> u64 {{
    let mut g: Guard<u64, Ebr> = Guard::new(h);
    let s = g.protect(cell).unwrap();
    g.reset(); // would drop the protection s relies on
    *s.get()
}}
"
    );
    assert_compile_fail("cf_use_after_reset", &src, &["E0499", "E0502", "E0503"]);
}

#[test]
fn shared_dies_on_reprotect() {
    let src = format!(
        "{PRELUDE}
pub fn reaim(h: &LocalHandle<Ebr>, a: &Atomic<u64, Ebr>, b: &Atomic<u64, Ebr>) -> u64 {{
    let mut g: Guard<u64, Ebr> = Guard::new(h);
    let s = g.protect(a).unwrap();
    let _t = g.protect(b); // re-aiming releases the protection on `s`
    *s.get()
}}
"
    );
    assert_compile_fail("cf_reprotect", &src, &["E0499", "E0502", "E0503"]);
}

#[test]
fn shared_blocks_retire() {
    let src = format!(
        "{PRELUDE}
pub unsafe fn retire_under_shared(h: &LocalHandle<Ebr>, cell: &Atomic<u64, Ebr>) -> u64 {{
    let mut g: Guard<u64, Ebr> = Guard::new(h);
    let s = g.protect(cell).unwrap();
    g.retire(); // cannot drop protection while `s` is alive
    *s.get()
}}
"
    );
    assert_compile_fail("cf_retire_under_shared", &src, &["E0499", "E0502", "E0503"]);
}

#[test]
fn guard_cannot_outlive_its_handle() {
    let src = format!(
        "{PRELUDE}
pub fn outlive() {{
    let g;
    {{
        let domain = emr::reclaim::DomainRef::<Ebr>::new_owned();
        let h = domain.register();
        g = Guard::<u64, Ebr>::new(&h); // `'h` brand ties g to h
    }}
    drop(g);
}}
"
    );
    assert_compile_fail("cf_guard_outlives_handle", &src, &["E0597", "E0716", "E0505"]);
}

#[test]
fn shared_cannot_escape_domain_resolution_scope() {
    let src = format!(
        "{PRELUDE}
pub fn escape_domain(cell: &Atomic<u64, Ebr>) -> u64 {{
    let domain = emr::reclaim::DomainRef::<Ebr>::new_owned();
    let out = domain.with_handle(|h| {{
        let mut g: Guard<u64, Ebr> = Guard::new(h);
        g.protect(cell).unwrap() // Shared cannot leave the closure
    }});
    *out.get()
}}
"
    );
    assert_compile_fail(
        "cf_escape_domain",
        &src,
        &["E0515", "E0597", "lifetime may not live long enough", "E0521"],
    );
}

//! End-to-end tests for the magazine (tcache) layer: the retire→reuse loop
//! across threads, the handle-drop flush contract, and LFRC's word-0
//! (type-stability) invariant surviving the full rack→depot→refill cycle.
//!
//! Every test here serialises on one lock: the magazine capacity is a
//! process-wide knob and the assertions depend on the layer being on (the
//! lib unit tests run in a different process, so only this binary's tests
//! can race each other). Pool size classes are picked per test so no two
//! tests (and none of the crate's own node traffic, which lands in the
//! small classes) share a free-list or depot.

use emr::alloc::{
    flush_magazines, magazine_stats, pool, set_magazine_cap, thread_cached_slots,
    DEFAULT_MAGAZINE_CAP,
};
use emr::reclaim::tests_common::{flush_until, Payload};
use emr::reclaim::{DomainRef, Owned, Reclaimer};
use std::alloc::Layout;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// Serialises the whole file (see module docs) and pins the default cap.
fn magazine_test_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    let g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    set_magazine_cap(DEFAULT_MAGAZINE_CAP);
    g
}

/// A thread frees a batch of slots and flushes; a *different* thread must
/// get exactly those slots back, via depot chains — the cross-thread leg
/// of the retire→reuse loop (nothing is stranded in the dead thread).
#[test]
fn cross_thread_reuse_via_depot() {
    let _g = magazine_test_lock();
    // 1 KiB class: exclusive to this test within this binary.
    let layout = Layout::from_size_align(1024, 8).unwrap();
    // More than one magazine, so the flush pushes multiple chains.
    const N: usize = DEFAULT_MAGAZINE_CAP + DEFAULT_MAGAZINE_CAP / 2;

    let before = magazine_stats();
    let mut freed: Vec<usize> = std::thread::spawn(move || {
        let ptrs: Vec<*mut u8> = (0..N).map(|_| pool::alloc(layout)).collect();
        let addrs: Vec<usize> = ptrs.iter().map(|&p| p as usize).collect();
        for p in ptrs {
            // SAFETY: freshly allocated above with this exact layout.
            unsafe { pool::free(p, layout) };
        }
        flush_magazines(); // rack → depot, at chain granularity
        assert_eq!(thread_cached_slots(), 0);
        addrs
    })
    .join()
    .unwrap();
    let mid = magazine_stats();
    assert!(
        mid.depot_flushes >= before.depot_flushes + 2,
        "expected ≥2 chain flushes for {N} slots"
    );

    let mut reused: Vec<usize> = std::thread::spawn(move || {
        let ptrs: Vec<*mut u8> = (0..N).map(|_| pool::alloc(layout)).collect();
        let addrs: Vec<usize> = ptrs.iter().map(|&p| p as usize).collect();
        for p in ptrs {
            // SAFETY: freshly allocated above with this exact layout.
            unsafe { pool::free(p, layout) };
        }
        flush_magazines();
        addrs
    })
    .join()
    .unwrap();
    assert!(magazine_stats().depot_refills > mid.depot_refills);

    freed.sort_unstable();
    reused.sort_unstable();
    assert_eq!(freed, reused, "second thread must drain exactly the first thread's slots");
}

/// Dropping the last handle on a thread flushes its rack: no slot may be
/// stranded in a dead thread's TLS (they all become visible in the depot),
/// and a later thread's allocations refill from there.
#[test]
fn handle_drop_flushes_thread_cache() {
    use emr::reclaim::ebr::Ebr;
    let _g = magazine_test_lock();
    const N: usize = 256;

    let domain = DomainRef::<Ebr>::new_owned();
    let drops = Arc::new(AtomicUsize::new(0));
    let before = magazine_stats();
    {
        let domain = domain.clone();
        let drops = drops.clone();
        std::thread::spawn(move || {
            let h = domain.register();
            for i in 0..N as u64 {
                h.retire_owned(Owned::<Payload, Ebr>::new(Payload::new(i, &drops)));
            }
            // Reclaim on this thread: the freed node slots land in its rack.
            assert!(flush_until(&h, || drops.load(Ordering::Relaxed) == N));
            assert!(thread_cached_slots() > 0, "reclaimed slots should sit in the rack");
            drop(h);
            // flush_until's *cached* domain handle is still alive in TLS,
            // but the rack flush on `h`'s drop is rack-wide: every slot
            // cached up to this point must have reached the depot.
            assert_eq!(
                thread_cached_slots(),
                0,
                "handle drop left slots stranded in thread-local magazines"
            );
        })
        .join()
        .unwrap();
    }
    let mid = magazine_stats();
    assert!(mid.depot_flushes > before.depot_flushes, "flush must hand chains to the depot");

    // Refill leg: a fresh allocation of the same class on *this* thread
    // (rack emptied first) must come from those depot chains.
    flush_magazines();
    let h = domain.register();
    h.retire_owned(Owned::<Payload, Ebr>::new(Payload::new(0, &drops)));
    assert!(flush_until(&h, || drops.load(Ordering::Relaxed) == N + 1));
    assert!(
        magazine_stats().depot_refills > mid.depot_refills,
        "allocation after a flush must refill from the depot"
    );
}

/// The slot's first word — LFRC's refcount word under the type-stability
/// contract — must survive the complete magazine round trip: free into a
/// rack, flush as a depot chain (links live at slot offsets 8/12), refill
/// on another thread, re-allocate.
#[test]
fn word0_survives_full_magazine_round_trip() {
    let _g = magazine_test_lock();
    // 2 KiB class: exclusive to this test within this binary.
    let layout = Layout::from_size_align(2048, 8).unwrap();
    const SENTINEL: u64 = 0xFEED_FACE_CAFE_BEEF;

    let p = pool::alloc(layout);
    // SAFETY: p is a live, exclusively-owned 2 KiB slot.
    unsafe { (p as *mut u64).write(SENTINEL) };
    // SAFETY: allocated above with this exact layout.
    unsafe { pool::free(p, layout) };
    flush_magazines();
    let addr = p as usize;

    std::thread::spawn(move || {
        let q = pool::alloc(layout);
        assert_eq!(q as usize, addr, "single depot chain must yield the same slot");
        // SAFETY: q is live and at least 8 bytes.
        let word0 = unsafe { (q as *const u64).read() };
        assert_eq!(word0, SENTINEL, "offset 0 was clobbered in the rack/depot cycle");
        // SAFETY: allocated above with this exact layout.
        unsafe { pool::free(q, layout) };
        flush_magazines();
    })
    .join()
    .unwrap();
}

/// Multi-thread node churn with magazines on, per scheme: drop-counting,
/// self-poisoning payloads catch any aliasing or double-reclamation the
/// magazine layer could introduce. LFRC's run additionally exercises its
/// forced-pool (type-stable refcount) traffic through the racks.
fn churn<R: Reclaimer>(threads: usize, per_thread: usize) {
    let _g = magazine_test_lock();
    let domain = DomainRef::<R>::new_owned();
    let drops = Arc::new(AtomicUsize::new(0));
    std::thread::scope(|scope| {
        for t in 0..threads {
            let domain = &domain;
            let drops = drops.clone();
            scope.spawn(move || {
                let h = domain.register();
                for i in 0..per_thread as u64 {
                    let v = t as u64 * per_thread as u64 + i;
                    h.retire_owned(Owned::<Payload, R>::new(Payload::new(v, &drops)));
                    if i % 64 == 0 {
                        h.flush();
                    }
                }
                h.flush();
            });
        }
    });
    let h = domain.register();
    let total = threads * per_thread;
    let ok = flush_until(&h, || drops.load(Ordering::Relaxed) == total);
    assert!(
        ok,
        "{}: churn leaked — {} of {} dropped",
        R::NAME,
        drops.load(Ordering::Relaxed),
        total
    );
}

macro_rules! churn_tests {
    ($($mod_name:ident => $scheme:ty),* $(,)?) => {$(
        mod $mod_name {
            use super::*;

            #[test]
            fn multi_thread_churn_with_magazines() {
                churn::<$scheme>(4, 300);
            }
        }
    )*};
}

churn_tests!(
    lfrc => emr::reclaim::lfrc::Lfrc,
    hp => emr::reclaim::hp::Hp,
    ebr => emr::reclaim::ebr::Ebr,
    nebr => emr::reclaim::nebr::Nebr,
    qsr => emr::reclaim::qsr::Qsr,
    debra => emr::reclaim::debra::Debra,
    stamp => emr::reclaim::stamp::StampIt,
    hyaline => emr::reclaim::hyaline::Hyaline,
);

//! Model-based property tests (seeded, shrinking — see `util::prop`):
//! random operation sequences run against both the lock-free structure and
//! a sequential model must agree; structural invariants must hold at every
//! step.

use emr::reclaim::leaky::Leaky;
use emr::reclaim::Cached;
use emr::reclaim::stamp::pool::{StampPool, NOT_IN_LIST, PENDING_PUSH, STAMP_INC};
use emr::util::prop::{check, check_ops, default_cases};
use emr::util::rng::Xoshiro256;
use std::collections::{BTreeMap, BTreeSet, VecDeque};

// ---- queue vs VecDeque ----------------------------------------------------

#[derive(Clone, Debug)]
enum QOp {
    Enq(u64),
    Deq,
}

#[test]
fn prop_queue_matches_vecdeque_model() {
    check_ops(
        "queue-model",
        0x51EE7,
        default_cases(),
        256,
        |rng| if rng.percent(55) { QOp::Enq(rng.next_u64()) } else { QOp::Deq },
        |ops| {
            let q: emr::ds::queue::Queue<u64, Leaky> = emr::ds::queue::Queue::new();
            let mut model = VecDeque::new();
            for op in ops {
                match op {
                    QOp::Enq(v) => {
                        q.enqueue(Cached, *v);
                        model.push_back(*v);
                    }
                    QOp::Deq => {
                        let got = q.dequeue(Cached);
                        let want = model.pop_front();
                        if got != want {
                            return Err(format!("dequeue: got {got:?}, model {want:?}"));
                        }
                    }
                }
            }
            if q.is_empty(Cached) != model.is_empty() {
                return Err("emptiness disagrees".into());
            }
            Ok(())
        },
        |ops| format!("{ops:?}"),
    );
}

// ---- list vs BTreeSet -------------------------------------------------------

#[derive(Clone, Debug)]
enum SOp {
    Insert(u8),
    Remove(u8),
    Contains(u8),
}

#[test]
fn prop_list_matches_btreeset_model() {
    check_ops(
        "list-model",
        0x115,
        default_cases(),
        256,
        |rng| {
            let k = rng.below(32) as u8;
            match rng.below(3) {
                0 => SOp::Insert(k),
                1 => SOp::Remove(k),
                _ => SOp::Contains(k),
            }
        },
        |ops| {
            let l: emr::ds::list::List<u8, (), Leaky> = emr::ds::list::List::new();
            let mut model = BTreeSet::new();
            for op in ops {
                let (got, want) = match op {
                    SOp::Insert(k) => (l.insert(Cached, *k, ()), model.insert(*k)),
                    SOp::Remove(k) => (l.remove(Cached, k), model.remove(k)),
                    SOp::Contains(k) => (l.contains(Cached, k), model.contains(k)),
                };
                if got != want {
                    return Err(format!("{op:?}: got {got}, model {want}"));
                }
            }
            if l.len(Cached) != model.len() {
                return Err(format!("len: {} vs model {}", l.len(Cached), model.len()));
            }
            Ok(())
        },
        |ops| format!("{ops:?}"),
    );
}

// ---- hashmap vs BTreeMap ----------------------------------------------------

#[derive(Clone, Debug)]
enum MOp {
    Insert(u16, u64),
    Remove(u16),
    Get(u16),
}

#[test]
fn prop_hashmap_matches_btreemap_model() {
    check_ops(
        "hashmap-model",
        0x4A54,
        default_cases(),
        256,
        |rng| {
            let k = rng.below(64) as u16;
            match rng.below(3) {
                0 => MOp::Insert(k, rng.next_u64()),
                1 => MOp::Remove(k),
                _ => MOp::Get(k),
            }
        },
        |ops| {
            let m: emr::ds::hashmap::HashMap<u16, u64, Leaky> =
                emr::ds::hashmap::HashMap::new(8);
            let mut model: BTreeMap<u16, u64> = BTreeMap::new();
            for op in ops {
                match op {
                    MOp::Insert(k, v) => {
                        let got = m.insert(Cached, *k, *v);
                        let want = !model.contains_key(k);
                        if want {
                            model.insert(*k, *v);
                        }
                        if got != want {
                            return Err(format!("insert {k}: got {got}, model {want}"));
                        }
                    }
                    MOp::Remove(k) => {
                        let got = m.remove(Cached, k);
                        let want = model.remove(k).is_some();
                        if got != want {
                            return Err(format!("remove {k}: got {got}, model {want}"));
                        }
                    }
                    MOp::Get(k) => {
                        let got = m.get(Cached, k, |v| *v);
                        let want = model.get(k).copied();
                        if got != want {
                            return Err(format!("get {k}: got {got:?}, model {want:?}"));
                        }
                    }
                }
            }
            if m.len() != model.len() {
                return Err(format!("len {} vs model {}", m.len(), model.len()));
            }
            Ok(())
        },
        |ops| format!("{ops:?}"),
    );
}

// ---- FIFO cache eviction model ----------------------------------------------

#[test]
fn prop_fifo_cache_evicts_in_insertion_order() {
    check("fifo-cache-model", 0xF1F0, default_cases(), |rng| {
        let cap = 1 + rng.below_usize(12);
        let cache: emr::ds::hashmap::FifoCache<u32, u32, Leaky> =
            emr::ds::hashmap::FifoCache::new(4, cap);
        let mut fifo: VecDeque<u32> = VecDeque::new();
        let n = 1 + rng.below_usize(64);
        for _ in 0..n {
            let k = rng.below(48) as u32;
            let inserted = cache.insert(Cached, k, k);
            let model_inserted = !fifo.contains(&k);
            if inserted != model_inserted {
                return Err(format!("insert {k}: {inserted} vs model {model_inserted}"));
            }
            if model_inserted {
                fifo.push_back(k);
                while fifo.len() > cap {
                    fifo.pop_front();
                }
            }
        }
        // Exact FIFO containment: single-threaded, so the model is exact.
        for &k in &fifo {
            if !cache.contains(Cached, &k) {
                return Err(format!("cache lost live key {k} (cap {cap})"));
            }
        }
        if cache.len() != fifo.len() {
            return Err(format!("len {} vs model {}", cache.len(), fifo.len()));
        }
        Ok(())
    });
}

// ---- stamp pool vs sequential model -----------------------------------------

/// Sequential model: the pool is an ordered multiset of stamps.
#[test]
fn prop_stamp_pool_matches_ordered_model() {
    check("stamp-pool-model", 0x57A4, default_cases(), |rng| {
        let pool = StampPool::new(64);
        // id -> (block idx, stamp); model: BTreeMap<stamp, id>
        let mut live: Vec<(u32, u64)> = Vec::new();
        let mut model: BTreeSet<u64> = BTreeSet::new();
        let mut highest = 0u64;
        let n = 1 + rng.below_usize(96);
        for _ in 0..n {
            if live.is_empty() || rng.percent(55) {
                let b = pool.alloc_block();
                let s = pool.push(b);
                if s <= highest {
                    return Err(format!("stamp {s} not strictly increasing (> {highest})"));
                }
                if s % STAMP_INC != 0 || s & (PENDING_PUSH | NOT_IN_LIST) != 0 {
                    return Err(format!("stamp {s} carries flag bits"));
                }
                highest = s;
                if pool.highest_stamp() != s {
                    return Err(format!(
                        "highest_stamp {} != last assigned {s}",
                        pool.highest_stamp()
                    ));
                }
                live.push((b, s));
                model.insert(s);
            } else {
                let i = rng.below_usize(live.len());
                let (b, s) = live.swap_remove(i);
                let was_lowest = model.iter().next() == Some(&s);
                let was_last = pool.remove(b);
                pool.free_block(b);
                model.remove(&s);
                if was_last != was_lowest {
                    return Err(format!(
                        "remove stamp {s}: was_last={was_last}, model lowest={was_lowest}"
                    ));
                }
            }
            // Safety bound: tail stamp never exceeds the lowest live stamp.
            if let Some(&lowest_live) = model.iter().next() {
                let tail = pool.lowest_stamp();
                if tail > lowest_live {
                    return Err(format!(
                        "tail stamp {tail} overtook live minimum {lowest_live}"
                    ));
                }
            }
        }
        // Drain; every removal of the current minimum must report last.
        while let Some(i) = (0..live.len()).min_by_key(|&i| live[i].1) {
            let (b, s) = live.swap_remove(i);
            let was_last = pool.remove(b);
            pool.free_block(b);
            model.remove(&s);
            if !was_last {
                return Err(format!("draining minimum {s} must be 'last'"));
            }
        }
        if pool.len_prev_list() != 0 {
            return Err("pool not empty after drain".into());
        }
        Ok(())
    });
}

// ---- marked pointer roundtrips -----------------------------------------------

#[test]
fn prop_marked_ptr_roundtrips() {
    check("marked-ptr", 0x3A11, default_cases(), |rng| {
        let node = emr::reclaim::alloc_node::<u64, Leaky>(rng.next_u64());
        for mark in 0..4usize {
            let p = emr::reclaim::MarkedPtr::<u64, Leaky>::new(node, mark);
            if p.get() != node || p.mark() != mark {
                return Err(format!("roundtrip failed for mark {mark}"));
            }
            let remark = rng.below_usize(4);
            let q = p.with_mark(remark);
            if q.get() != node || q.mark() != remark {
                return Err("with_mark corrupted pointer".into());
            }
        }
        unsafe { emr::reclaim::free_node(node) };
        Ok(())
    });
}

// ---- payload compute determinism ----------------------------------------------

#[test]
fn prop_payload_compute_deterministic() {
    check("payload-compute", 0xBEEF, default_cases(), |rng| {
        let key = rng.next_u64();
        let a = emr::bench_fw::workload::compute_payload(key);
        let b = emr::bench_fw::workload::compute_payload(key);
        if a != b {
            return Err(format!("nondeterministic payload for key {key}"));
        }
        let other = emr::bench_fw::workload::compute_payload(key.wrapping_add(1));
        if a == other {
            return Err("adjacent keys produced identical payloads".into());
        }
        Ok(())
    });
}

// ---- prng sanity ---------------------------------------------------------------

#[test]
fn prop_rng_streams_do_not_collide() {
    check("rng-streams", 7, 16, |rng| {
        let s1 = rng.next_u64();
        let s2 = rng.next_u64();
        if s1 == s2 {
            return Ok(()); // astronomically unlikely; not an error per se
        }
        let mut a = Xoshiro256::new(s1);
        let mut b = Xoshiro256::new(s2);
        let collisions = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        if collisions > 0 {
            return Err(format!("{collisions} collisions between distinct streams"));
        }
        Ok(())
    });
}

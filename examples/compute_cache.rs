//! **End-to-end driver (experiment E15).** Exercises all three layers on a
//! real workload: Rust coordinator (L3) serving batched requests through
//! the Stamp-it-reclaimed lock-free cache, dispatching misses to the
//! AOT-compiled JAX (L2) + Pallas (L1) computation via PJRT.
//!
//! ```bash
//! make artifacts && cargo run --release --example compute_cache -- \
//!     --scheme stamp --clients 4 --requests 2000
//! # sharded fleet, artifact-free (2 engine groups — one batcher each):
//! cargo run --release --example compute_cache -- \
//!     --backend synthetic --shards 4 --groups 2 --clients 8
//! # async front-end: 10k logical clients multiplexed on 8 executor threads
//! cargo run --release --example compute_cache -- \
//!     --backend synthetic --shards 4 --frontend async --clients 10000 --requests 10
//! # TCP front-end: real loopback sockets through the net reactor
//! cargo run --release --example compute_cache -- \
//!     --backend synthetic --shards 4 --frontend net --clients 1000 --requests 10
//! ```
//!
//! Reports throughput, latency percentiles (hit vs computed), cache hit
//! rate, and the paper's reclamation-efficiency metric — rolled up and,
//! when `--shards N > 1`, per shard. `--groups N` partitions the fleet into
//! engine groups (one batcher/engine thread each, DESIGN.md §9; per-group
//! batch counters are printed when N > 1). `--shared-domain` switches the
//! fleet from domain-per-shard to one shared reclamation domain. `--frontend
//! async` drives the same load as logical tasks over the completion-driven
//! submission path (DESIGN.md §6) instead of one OS thread per client;
//! `--frontend net` drives it as framed requests over real TCP connections
//! through the reactor (DESIGN.md §8, `--listen ADDR` to pin the address).
//! Recorded in EXPERIMENTS.md §E15/§E16/§E17/§E18.

use emr::coordinator::frontend::mux::{self, MuxConfig};
use emr::coordinator::frontend::net::client::{storm, NetClient, StormConfig};
use emr::coordinator::frontend::net::{NetConfig, NetServer};
use emr::coordinator::frontend::Frontend;
use emr::coordinator::{Backend, CacheServer, ServerConfig};
use emr::dispatch_scheme;
use emr::reclaim::{Reclaimer, SchemeId};
use emr::runtime::exec::Executor;
use emr::util::cli::Args;
use emr::util::rng::Xoshiro256;
use emr::util::stats::{fmt_ns, percentile_sorted};

struct Opts {
    clients: usize,
    requests: usize,
    key_space: u64,
    hot_pct: usize,
    /// Which front-end drives the load: client threads, the async mux, or
    /// real TCP connections through the net reactor.
    frontend: Frontend,
    exec_threads: usize,
    /// Bind address for `--frontend net` (port 0 = ephemeral).
    listen: std::net::SocketAddr,
    cfg: ServerConfig,
}

fn main() {
    let args = Args::parse();
    let scheme = SchemeId::parse(args.get_or("scheme", "stamp")).expect("unknown --scheme");
    let cfg = ServerConfig {
        capacity: args.usize_or("capacity", 10_000),
        workers: 2,
        ..ServerConfig::default()
    }
    .with_shards(args.usize_or("shards", 1))
    .with_groups(args.usize_or("groups", 1))
    .with_shared_domain(args.flag("shared-domain"))
    .with_backend(
        Backend::parse(args.get_or("backend", "pjrt")).expect("unknown --backend"),
    );
    let opts = Opts {
        clients: args.usize_or("clients", 4),
        requests: args.usize_or("requests", 2000),
        key_space: args.u64_or("keys", 30_000),
        hot_pct: args.usize_or("hot-pct", 80), // % of requests on a hot set
        frontend: Frontend::parse(args.get_or("frontend", "thread")).unwrap_or_else(|| {
            eprintln!("unknown --frontend ({})", Frontend::NAMES);
            std::process::exit(2);
        }),
        exec_threads: args.usize_or("exec-threads", 8),
        listen: args.get_or("listen", "127.0.0.1:0").parse().unwrap_or_else(|e| {
            eprintln!("bad --listen address: {e}");
            std::process::exit(2);
        }),
        cfg,
    };
    dispatch_scheme!(scheme, run, opts);
}

fn run<R: Reclaimer>(opts: Opts) {
    let Opts { clients, requests, key_space, hot_pct, frontend, exec_threads, listen, cfg } =
        opts;
    if cfg.backend == Backend::Pjrt && !emr::runtime::artifacts_available() {
        eprintln!("no artifacts — run `make artifacts` first (or --backend synthetic)");
        std::process::exit(1);
    }
    let shards = cfg.shards;
    let shared_domain = cfg.shared_domain;
    let capacity = cfg.capacity;
    let server = CacheServer::<R>::start(cfg).expect("server start");

    let frontend_desc = match frontend {
        Frontend::Thread => "thread".to_string(),
        Frontend::Async => format!("async ({exec_threads} executor threads)"),
        Frontend::Net => format!("net ({exec_threads} executor threads, TCP loopback)"),
    };
    println!(
        "E15 compute-cache: scheme={} clients={clients} requests/client={requests} \
         keys={key_space} capacity={capacity} hot={hot_pct}% shards={shards} \
         groups={} domains={} frontend={frontend_desc}",
        R::NAME,
        server.group_count(),
        if shared_domain { "shared".to_string() } else { format!("{shards} (per shard)") },
    );
    let alloc_before = emr::alloc::snapshot();
    let t0 = emr::util::monotonic_ns();

    // Client load: hot_pct% of requests hit a small hot set (cache-friendly,
    // like reused partial results), the rest are uniform over the key space.
    // `--frontend async` issues the identical load as logical tasks
    // multiplexed over the completion-driven submission path; `--frontend
    // net` issues it as framed requests over real loopback TCP connections.
    // The net server outlives the branch so its listener counters stay
    // registered for the `server.metrics()` rollup printed below.
    let mut net_server: Option<NetServer> = None;
    let (mut hits, mut misses): (Vec<f64>, Vec<f64>) = match frontend {
        Frontend::Async => {
            let exec = Executor::new(exec_threads);
            let report = mux::drive(
                &exec,
                server.clone(),
                &MuxConfig {
                    clients,
                    requests_per_client: requests,
                    key_space,
                    hot_pct: hot_pct as u32,
                    shard_in_flight: 256,
                    seed: 0xE15,
                },
            );
            assert_eq!(report.errors, 0, "no request may be dropped");
            (
                report.hit_ns.iter().map(|&n| n as f64).collect(),
                report.miss_ns.iter().map(|&n| n as f64).collect(),
            )
        }
        Frontend::Net => {
            let net = NetServer::start(
                server.clone(),
                NetConfig { listen, exec_threads, ..NetConfig::default() },
            )
            .expect("net front start");
            println!("listening on {}", net.local_addr());
            let report = storm(
                net.local_addr(),
                &StormConfig {
                    conns: clients,
                    requests_per_conn: requests,
                    key_space,
                    hot_pct: hot_pct as u32,
                    seed: 0xE15,
                    ..StormConfig::default()
                },
            );
            assert_eq!(report.errors, 0, "no request may be dropped");
            net_server = Some(net);
            (
                report.hit_ns.iter().map(|&n| n as f64).collect(),
                report.miss_ns.iter().map(|&n| n as f64).collect(),
            )
        }
        Frontend::Thread => {
            let per_client: Vec<(Vec<f64>, Vec<f64>)> = std::thread::scope(|scope| {
                let handles: Vec<_> = (0..clients)
                    .map(|c| {
                        let server = &server;
                        scope.spawn(move || {
                            let mut rng = Xoshiro256::new(0xE15 ^ c as u64);
                            let mut hit_lat = Vec::new();
                            let mut miss_lat = Vec::new();
                            for _ in 0..requests {
                                let key = rng.skewed_key(key_space, hot_pct as u32);
                                let resp = server.request(key).expect("request");
                                assert!(resp
                                    .data
                                    .iter()
                                    .all(|v| v.is_finite() && v.abs() <= 1.0));
                                if resp.hit {
                                    hit_lat.push(resp.latency_ns as f64);
                                } else {
                                    miss_lat.push(resp.latency_ns as f64);
                                }
                            }
                            (hit_lat, miss_lat)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            (
                per_client.iter().flat_map(|(h, _)| h.iter().copied()).collect(),
                per_client.iter().flat_map(|(_, m)| m.iter().copied()).collect(),
            )
        }
    };
    let wall_s = (emr::util::monotonic_ns() - t0) as f64 / 1e9;

    hits.sort_by(|a, b| a.partial_cmp(b).unwrap());
    misses.sort_by(|a, b| a.partial_cmp(b).unwrap());

    let total = (clients * requests) as f64;
    println!(
        "\nthroughput      : {:.0} req/s ({total:.0} requests in {wall_s:.2}s)",
        total / wall_s
    );
    for (name, lat) in [("hit", &hits), ("computed", &misses)] {
        if lat.is_empty() {
            continue;
        }
        println!(
            "latency {name:<8}: p50={} p95={} p99={}  (n={})",
            fmt_ns(percentile_sorted(lat, 50.0)),
            fmt_ns(percentile_sorted(lat, 95.0)),
            fmt_ns(percentile_sorted(lat, 99.0)),
            lat.len()
        );
    }
    let m = server.metrics();
    println!("server          : {m}");
    if server.shard_count() > 1 {
        for (i, sm) in server.shard_metrics().iter().enumerate() {
            println!("  shard {i}       : {sm}");
        }
    }
    if server.group_count() > 1 {
        for gm in server.group_metrics() {
            println!("  {gm}");
        }
    }
    println!("cache entries   : {}", server.cache_len());
    match frontend {
        Frontend::Async => {
            // The mux reports latencies, not payloads — spot-check data
            // validity through the same async path the load just exercised
            // (the thread branch asserts this per response). After the timed
            // window AND the metric printouts, so neither the async-vs-thread
            // throughput comparison nor the reported counters are skewed.
            for key in 0..8u32 {
                let resp = emr::runtime::exec::block_on(server.submit_async(key))
                    .expect("post-run probe");
                assert!(resp.data.iter().all(|v| v.is_finite() && v.abs() <= 1.0));
            }
        }
        Frontend::Net => {
            // Same spot-check, but through the wire: a fresh connection
            // round-trips a few keys so payload encode/decode is verified
            // end-to-end before the listener goes away.
            let net = net_server.as_ref().expect("net server alive");
            let mut probe = NetClient::connect(net.local_addr()).expect("post-run connect");
            for key in 0..8u32 {
                let frame = probe.request(key).expect("post-run probe");
                let data = frame.data.expect("ok response carries a payload");
                assert!(data.iter().all(|v| v.is_finite() && v.abs() <= 1.0));
            }
        }
        Frontend::Thread => {}
    }
    if let Some(mut net) = net_server.take() {
        // Drain in-flight completions, flush outboxes, close the listener.
        net.shutdown();
    }
    server.shutdown();
    // The server owns its reclamation domain; dropping the last reference
    // drains every node still parked there (worker handles already released
    // theirs at join), settling the counters for the report below.
    drop(server);
    let alloc_after = emr::alloc::snapshot();
    println!(
        "nodes           : allocated {} reclaimed {} (unreclaimed at exit: {})",
        alloc_after.allocated - alloc_before.allocated,
        alloc_after.reclaimed - alloc_before.reclaimed,
        emr::alloc::unreclaimed()
    );
}

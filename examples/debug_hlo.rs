//! Diagnostic: run one artifact directly and dump result metadata.
//! (Kept as a debugging aid; not part of the documented example set.
//! Requires the `pjrt` feature — see `rust/src/runtime`.)

use emr::anyhow;
use emr::util::error::Result;

#[cfg(feature = "pjrt")]
fn main() -> Result<()> {
    let path = std::env::args().nth(1).unwrap_or_else(|| "artifacts/model_b1.hlo.txt".into());
    let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("{e:?}"))?;
    println!("platform={}", client.platform_name());
    let proto = xla::HloModuleProto::from_text_file(&path).map_err(|e| anyhow!("{e:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp).map_err(|e| anyhow!("{e:?}"))?;
    let input = xla::Literal::vec1(&[1i32]);
    println!("input ty={:?} count={}", input.ty(), input.element_count());
    let result = exe.execute::<xla::Literal>(&[input]).map_err(|e| anyhow!("{e:?}"))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("{e:?}"))?;
    let shape = result.shape().map_err(|e| anyhow!("{e:?}"))?;
    println!("shape={shape:?}");
    let tup = result.to_tuple1().map_err(|e| anyhow!("{e:?}"))?;
    println!("elem ty={:?} count={}", tup.ty(), tup.element_count());
    let v: Vec<f32> = tup.to_vec().map_err(|e| anyhow!("{e:?}"))?;
    println!("first8={:?}", &v[..8]);
    let nz = v.iter().filter(|x| **x != 0.0).count();
    println!("nonzero={nz}/{}", v.len());
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn main() -> Result<()> {
    Err(anyhow!("debug_hlo needs the `pjrt` feature (and the xla crate) — see rust/src/runtime"))
}

//! Reclamation-efficiency demo (paper §4.4 in miniature): churn a queue
//! and the HashMap-benchmark cache under a chosen scheme while printing the
//! unreclaimed-node counter — watch epochs lag, hazard-pointer thresholds
//! plateau, and Stamp-it track the working set.
//!
//! The whole run lives in one **owned reclamation domain**; worker threads
//! use explicit per-thread handles (the TLS-free fast path).
//!
//! ```bash
//! cargo run --release --example reclamation_stress -- --scheme debra --secs 2
//! cargo run --release --example reclamation_stress -- --scheme stamp --secs 2
//! ```

use emr::bench_fw::workload::{compute_payload, consume_payload};
use emr::dispatch_scheme;
use emr::ds::hashmap::FifoCache;
use emr::ds::queue::Queue;
use emr::reclaim::{DomainRef, Reclaimer, SchemeId};
use emr::util::cli::Args;
use emr::util::rng::Xoshiro256;
use std::sync::atomic::{AtomicBool, Ordering};

fn main() {
    let args = Args::parse();
    let scheme = SchemeId::parse(args.get_or("scheme", "stamp")).expect("unknown --scheme");
    let secs = args.f64_or("secs", 1.0);
    let threads = args.usize_or("threads", 4);
    dispatch_scheme!(scheme, run, secs, threads);
}

fn run<R: Reclaimer>(secs: f64, threads: usize) {
    println!("reclamation stress under {} — {threads} threads, {secs}s", R::NAME);
    let domain = DomainRef::<R>::new_owned();
    let queue: Queue<u64, R> = Queue::new_in(domain.clone());
    let cache: FifoCache<u64, [f32; 256], R> = FifoCache::new_in(domain.clone(), 256, 1000);
    let stop = AtomicBool::new(false);
    let start = emr::alloc::snapshot();

    std::thread::scope(|scope| {
        for t in 0..threads {
            let queue = &queue;
            let cache = &cache;
            let stop = &stop;
            scope.spawn(move || {
                let h = queue.domain().register();
                let mut rng = Xoshiro256::new(0x57E5 ^ t as u64);
                let mut sink = 0.0f32;
                while !stop.load(Ordering::Acquire) {
                    // Queue churn: retire a steady stream of small nodes.
                    queue.enqueue(&h, rng.next_u64());
                    queue.dequeue(&h);
                    // Cache churn: evictions retire 1 KiB nodes.
                    let key = rng.below(5_000);
                    match cache.get(&h, &key, consume_payload) {
                        Some(v) => sink += v,
                        None => {
                            cache.insert(&h, key, compute_payload(key));
                        }
                    }
                }
                std::hint::black_box(sink);
            });
        }
        // Sampler: print the counter ten times over the run.
        let interval = std::time::Duration::from_secs_f64(secs / 10.0);
        println!("{:>6} {:>12} {:>12} {:>12}", "t", "allocated", "reclaimed", "unreclaimed");
        for i in 1..=10 {
            std::thread::sleep(interval);
            let s = emr::alloc::snapshot();
            println!(
                "{:>5.1}s {:>12} {:>12} {:>12}",
                i as f64 * secs / 10.0,
                s.allocated - start.allocated,
                s.reclaimed - start.reclaimed,
                emr::alloc::unreclaimed()
            );
        }
        stop.store(true, Ordering::Release);
    });

    drop(queue);
    drop(cache);
    // Final flush through a fresh handle, then drop the last domain
    // reference (drains whatever remains).
    let h = domain.register();
    h.flush();
    drop(h);
    drop(domain);
    println!("after shutdown+flush: unreclaimed={}", emr::alloc::unreclaimed());
}

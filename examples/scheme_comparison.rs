//! Quick scheme comparison — a pocket edition of the paper's Figure 4:
//! run the List benchmark across all seven schemes and print the
//! per-operation cost table.
//!
//! ```bash
//! cargo run --release --example scheme_comparison -- --threads 1,2,4 --secs 0.5
//! ```

use emr::bench_fw::figures::{fig_throughput, Workload};
use emr::bench_fw::BenchParams;
use emr::util::cli::Args;

fn main() {
    let args = Args::parse();
    let mut p = BenchParams::from_args(&args);
    if args.get("secs").is_none() {
        p.secs = 0.25;
    }
    if args.get("trials").is_none() {
        p.trials = 2;
    }
    emr::bench_fw::report::print_environment();
    fig_throughput(&p, Workload::List);
    println!(
        "\n(LFRC's penalty is the per-hop refcount CAS pair; the epoch family\n\
         and Stamp-it pay only region entry/exit — see the paper's Fig. 4.)"
    );
}

//! Quickstart: the reclamation interface in 5 minutes.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Shows the three benchmark data structures under Stamp-it, plus how to
//! pick a different scheme (one type parameter) and how to observe the
//! allocation/reclamation counters the paper's efficiency analysis uses.

use emr::ds::hashmap::FifoCache;
use emr::ds::list::List;
use emr::ds::queue::Queue;
use emr::reclaim::ebr::Ebr;
use emr::reclaim::stamp::StampIt;
use emr::reclaim::{Reclaimer, Region};

fn main() {
    // --- a Michael-Scott queue, reclaimed by Stamp-it ------------------
    let queue: Queue<u64, StampIt> = Queue::new();
    std::thread::scope(|s| {
        for t in 0..4u64 {
            let queue = &queue;
            s.spawn(move || {
                // A region_guard amortizes the critical-region entry over
                // many operations (paper §2).
                let _region = Region::<StampIt>::enter();
                for i in 0..1000 {
                    queue.enqueue(t * 1000 + i);
                    if i % 2 == 0 {
                        queue.dequeue();
                    }
                }
            });
        }
    });
    let mut drained = 0;
    while queue.dequeue().is_some() {
        drained += 1;
    }
    println!("queue: drained {drained} values");

    // --- a Harris-Michael set: same structure, different scheme --------
    let set: List<u64, (), Ebr> = List::new();
    for k in [3, 1, 4, 1, 5, 9, 2, 6] {
        set.insert(k, ());
    }
    println!("set: len={} contains(4)={} (duplicate 1 rejected)", set.len(), set.contains(&4));
    set.remove(&4);
    println!("set: after remove, contains(4)={}", set.contains(&4));

    // --- the paper's HashMap-benchmark cache ---------------------------
    let cache: FifoCache<u64, [u8; 1024], StampIt> = FifoCache::new(64, 100);
    for key in 0..300u64 {
        cache.insert(key, [key as u8; 1024]);
    }
    println!(
        "cache: {} entries after 300 inserts into capacity 100 (FIFO eviction)",
        cache.len()
    );

    // --- the efficiency metric -----------------------------------------
    StampIt::flush();
    Ebr::flush();
    println!(
        "counters: allocated={} reclaimed={} unreclaimed={}",
        emr::alloc::allocated(),
        emr::alloc::reclaimed(),
        emr::alloc::unreclaimed()
    );
}

//! Quickstart: the reclamation interface in 5 minutes.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Shows the three benchmark data structures under Stamp-it, plus how to
//! pick a different scheme (one type parameter), how to isolate work in its
//! own reclamation domain with a cached per-thread handle (the fast path),
//! and how to observe the allocation/reclamation counters the paper's
//! efficiency analysis uses.

use emr::ds::hashmap::FifoCache;
use emr::ds::list::List;
use emr::ds::queue::Queue;
use emr::reclaim::ebr::Ebr;
use emr::reclaim::stamp::StampIt;
use emr::reclaim::{Cached, DomainRef, Region};

fn main() {
    // --- a Michael-Scott queue, reclaimed by Stamp-it ------------------
    // `Queue::new()` uses the process-wide global domain. `Cached` resolves
    // the thread's cached handle (one TLS lookup) — the quickstart path;
    // passing `&handle` instead is the TLS-free fast path.
    let queue: Queue<u64, StampIt> = Queue::new();
    std::thread::scope(|s| {
        for t in 0..4u64 {
            let queue = &queue;
            s.spawn(move || {
                // The fast path: register once, then every region, guard
                // and retire goes through the handle — no TLS, no RefCell.
                let handle = queue.domain().register();
                // A region_guard amortizes the critical-region entry over
                // many operations (paper §2).
                let _region = Region::enter(&handle);
                for i in 0..1000 {
                    queue.enqueue(&handle, t * 1000 + i);
                    if i % 2 == 0 {
                        queue.dequeue(&handle);
                    }
                }
            });
        }
    });
    let mut drained = 0;
    while queue.dequeue(Cached).is_some() {
        drained += 1;
    }
    println!("queue: drained {drained} values");

    // --- a Harris-Michael set: same structure, different scheme --------
    let set: List<u64, (), Ebr> = List::new();
    for k in [3, 1, 4, 1, 5, 9, 2, 6] {
        set.insert(Cached, k, ());
    }
    println!(
        "set: len={} contains(4)={} (duplicate 1 rejected)",
        set.len(Cached),
        set.contains(Cached, &4)
    );
    set.remove(Cached, &4);
    println!("set: after remove, contains(4)={}", set.contains(Cached, &4));

    // --- the paper's HashMap-benchmark cache, in its own domain --------
    // `new_in` + an owned domain = an isolated reclamation universe: its
    // retired nodes never mix with the global domain's, and once the last
    // reference (cache + this thread's cached handle) goes away the domain
    // drains everything it still holds.
    let cache: FifoCache<u64, [u8; 1024], StampIt> =
        FifoCache::new_in(DomainRef::new_owned(), 64, 100);
    for key in 0..300u64 {
        cache.insert(Cached, key, [key as u8; 1024]);
    }
    println!(
        "cache: {} entries after 300 inserts into capacity 100 (FIFO eviction)",
        cache.len()
    );

    // --- the efficiency metric -----------------------------------------
    DomainRef::<StampIt>::global().with_handle(|h| h.flush());
    DomainRef::<Ebr>::global().with_handle(|h| h.flush());
    println!(
        "counters: allocated={} reclaimed={} unreclaimed={}",
        emr::alloc::allocated(),
        emr::alloc::reclaimed(),
        emr::alloc::unreclaimed()
    );
}

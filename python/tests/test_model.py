"""Layer-2 model tests: shapes, determinism, oracle agreement."""

import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile.model import DIM, K_STEPS, make_weights, partial_result, partial_result_ref


def _seeds(batch, lo=0, hi=30000, seed=0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randint(lo, hi, size=(batch,), dtype=np.int32))


def test_output_shape_and_payload_size():
    for b in (1, 8, 32):
        (out,) = partial_result(_seeds(b))
        assert out.shape == (b, DIM)
        assert out.dtype == jnp.float32
        # The paper's HashMap payload: 1024 bytes per result.
        assert out.shape[1] * 4 == 1024


def test_model_matches_ref():
    seeds = _seeds(8, seed=3)
    (got,) = partial_result(seeds)
    want = partial_result_ref(seeds)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_model_is_deterministic():
    seeds = _seeds(8, seed=1)
    (a,) = partial_result(seeds)
    (b,) = partial_result(seeds)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_results_differ_per_seed():
    (out,) = partial_result(jnp.asarray(np.array([1, 2, 3, 4], dtype=np.int32)))
    out = np.asarray(out)
    for i in range(len(out)):
        for j in range(i + 1, len(out)):
            assert not np.allclose(out[i], out[j]), f"rows {i},{j} identical"


def test_batch_invariance():
    # A seed's result must not depend on its batch neighbours (the batcher
    # pads batches; padding must not perturb real results).
    s = _seeds(4, seed=9)
    (batched,) = partial_result(s)
    for i in range(4):
        (single,) = partial_result(s[i : i + 1])
        assert_allclose(
            np.asarray(single)[0], np.asarray(batched)[i], rtol=1e-5, atol=1e-6
        )


def test_values_bounded_and_finite():
    (out,) = partial_result(_seeds(32, seed=4))
    out = np.asarray(out)
    assert np.all(np.isfinite(out))
    assert np.all(np.abs(out) <= 1.0)  # tanh output
    # And not degenerate (all-zero / collapsed).
    assert np.std(out) > 1e-3


def test_weights_are_reproducible():
    w1, b1 = make_weights()
    w2, b2 = make_weights()
    np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))
    assert w1.shape == (DIM, DIM)
    assert K_STEPS >= 1

"""AOT artifact tests: the HLO-text bridge the Rust runtime consumes."""

import pathlib

import pytest

from compile.aot import DEFAULT_BATCHES, lower_to_hlo_text, write_artifacts


def test_hlo_text_structure():
    text = lower_to_hlo_text(1)
    assert "ENTRY" in text, "must be a complete HLO module"
    assert "custom-call" not in text, "Mosaic custom-call would be unloadable on CPU PJRT"
    # Regression: the printer must not elide the model weights — the 0.5.1
    # text parser reads `constant({...})` placeholders as zeros.
    assert "constant({...})" not in text, "large constants elided from HLO text"
    # One int32 batch input, one tupled f32 output.
    assert "s32[1]" in text
    assert "f32[1,256]" in text


@pytest.mark.parametrize("batch", DEFAULT_BATCHES)
def test_hlo_text_per_batch_shapes(batch):
    text = lower_to_hlo_text(batch)
    assert f"s32[{batch}]" in text
    assert f"f32[{batch},256]" in text


def test_write_artifacts_layout(tmp_path: pathlib.Path):
    paths = write_artifacts(tmp_path, [1, 8])
    assert [p.name for p in paths] == ["model_b1.hlo.txt", "model_b8.hlo.txt"]
    for p in paths:
        assert p.exists()
        content = p.read_text()
        assert len(content) > 1000, "suspiciously small HLO module"
        assert "ENTRY" in content


def test_lowering_is_reproducible():
    assert lower_to_hlo_text(8) == lower_to_hlo_text(8)

"""Kernel vs oracle — the CORE correctness signal (hypothesis sweeps)."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.kernels import feature_expand, fused_step
from compile.kernels.ref import feature_expand_ref, fused_step_ref

hypothesis.settings.register_profile(
    "kernels", max_examples=25, deadline=None, derandomize=True
)
hypothesis.settings.load_profile("kernels")


def _rand(shape, dtype, seed):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.uniform(-1.0, 1.0, size=shape).astype(dtype))


# ---- fused_step -----------------------------------------------------------


@hypothesis.given(
    batch=st.sampled_from([1, 2, 3, 5, 8, 32, 64, 96]),
    k=st.sampled_from([8, 64, 128, 256]),
    n=st.sampled_from([8, 128, 256, 384]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_step_matches_ref_f32(batch, k, n, seed):
    x = _rand((batch, k), np.float32, seed)
    w = _rand((k, n), np.float32, seed + 1)
    b = _rand((n,), np.float32, seed + 2)
    got = fused_step(x, w, b)
    want = fused_step_ref(x, w, b)
    assert got.shape == (batch, n)
    assert got.dtype == x.dtype
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@hypothesis.given(
    batch=st.sampled_from([1, 4, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_step_matches_ref_bf16(batch, seed):
    # bf16 inputs with f32 accumulation: loose elementwise tolerance.
    x = _rand((batch, 128), np.float32, seed).astype(jnp.bfloat16)
    w = _rand((128, 128), np.float32, seed + 1).astype(jnp.bfloat16)
    b = _rand((128,), np.float32, seed + 2).astype(jnp.bfloat16)
    got = fused_step(x, w, b)
    want = fused_step_ref(x, w, b)
    assert got.dtype == jnp.bfloat16
    assert_allclose(
        np.asarray(got, dtype=np.float32),
        np.asarray(want, dtype=np.float32),
        rtol=0.05,
        atol=0.05,
    )


def test_fused_step_output_is_tanh_bounded():
    x = _rand((8, 256), np.float32, 0) * 100.0
    w = _rand((256, 256), np.float32, 1)
    b = _rand((256,), np.float32, 2)
    y = np.asarray(fused_step(x, w, b))
    assert np.all(np.abs(y) <= 1.0)
    assert np.all(np.isfinite(y))


def test_fused_step_rejects_contraction_mismatch():
    x = _rand((4, 64), np.float32, 0)
    w = _rand((128, 128), np.float32, 1)
    b = _rand((128,), np.float32, 2)
    with pytest.raises(AssertionError):
        fused_step(x, w, b)


def test_fused_step_tiling_boundaries_agree():
    # A shape whose batch is not a multiple of the 64 target forces the
    # divisor-search tiling path; values must not depend on tiling.
    x = _rand((96, 256), np.float32, 7)
    w = _rand((256, 384), np.float32, 8)
    b = _rand((384,), np.float32, 9)
    got = np.asarray(fused_step(x, w, b))
    want = np.asarray(fused_step_ref(x, w, b))
    assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---- feature_expand -------------------------------------------------------


@hypothesis.given(
    batch=st.sampled_from([1, 2, 7, 8, 32, 96]),
    dim=st.sampled_from([8, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_feature_expand_matches_ref(batch, dim, seed):
    rng = np.random.RandomState(seed)
    seeds = jnp.asarray(rng.randint(0, 30000, size=(batch,), dtype=np.int32))
    got = feature_expand(seeds, dim)
    want = feature_expand_ref(seeds, dim)
    assert got.shape == (batch, dim)
    assert got.dtype == jnp.float32
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_feature_expand_is_deterministic_and_seed_sensitive():
    seeds = jnp.asarray(np.arange(16, dtype=np.int32))
    a = np.asarray(feature_expand(seeds))
    b = np.asarray(feature_expand(seeds))
    np.testing.assert_array_equal(a, b)
    other = np.asarray(feature_expand(seeds + 1))
    assert not np.allclose(a, other), "different seeds must give different features"


def test_feature_expand_values_bounded():
    seeds = jnp.asarray(np.arange(64, dtype=np.int32) * 1000)
    y = np.asarray(feature_expand(seeds))
    assert np.all(np.abs(y) <= 1.0)


# ---- pallas vs jit composition -------------------------------------------


def test_kernels_compose_under_jit():
    @jax.jit
    def pipeline(seeds, w, b):
        x = feature_expand(seeds, 256)
        return fused_step(x, w, b)

    seeds = jnp.asarray(np.arange(8, dtype=np.int32))
    w = _rand((256, 256), np.float32, 3)
    b = _rand((256,), np.float32, 4)
    got = pipeline(seeds, w, b)
    want = fused_step_ref(feature_expand_ref(seeds, 256), w, b)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

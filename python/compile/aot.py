"""AOT bridge: lower the Layer-2 model to HLO **text** artifacts.

One artifact per compiled batch size (``model_b{B}.hlo.txt``): PJRT
executables have static shapes, so the Rust batcher pads to the nearest
compiled size.

HLO *text* — NOT ``lowered.compile()`` / serialized protos — is the
interchange format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction
ids which the pinned xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Usage (invoked by ``make artifacts``; the ONLY Python the system ever runs):

    cd python && python -m compile.aot --out-dir ../artifacts --batches 1 8 32
"""

import argparse
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import partial_result

#: Batch sizes compiled by default (mirrored in rust/src/runtime).
DEFAULT_BATCHES = (1, 8, 32)


def lower_to_hlo_text(batch: int) -> str:
    """Lower ``partial_result`` for one batch size to HLO text."""
    spec = jax.ShapeDtypeStruct((batch,), jnp.int32)
    lowered = jax.jit(partial_result).lower(spec)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default printer elides big literals as
    # `constant({...})`, which the 0.5.1 text parser silently reads as zeros
    # (the model weights would vanish).
    text = comp.as_hlo_text(True)
    # interpret=True means no Mosaic custom-calls may remain — anything
    # else would be unloadable by the CPU PJRT client.
    assert "custom-call" not in text, "kernel lowered to a custom-call (interpret=False?)"
    return text


def write_artifacts(out_dir: pathlib.Path, batches) -> list[pathlib.Path]:
    out_dir.mkdir(parents=True, exist_ok=True)
    paths = []
    for b in batches:
        text = lower_to_hlo_text(b)
        path = out_dir / f"model_b{b}.hlo.txt"
        path.write_text(text)
        print(f"wrote {path} ({len(text)} chars)")
        paths.append(path)
    return paths


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--batches", type=int, nargs="+", default=list(DEFAULT_BATCHES))
    args = parser.parse_args()
    write_artifacts(pathlib.Path(args.out_dir), args.batches)


if __name__ == "__main__":
    main()

"""Layer-2 JAX model: the "partial result" computation served by the
coordinator.

``partial_result(seeds)`` maps a batch of int32 seeds to 256-float
(1024-byte — exactly the paper's HashMap-benchmark node payload, §4.1)
results: a Pallas feature expansion followed by ``K_STEPS`` scanned
applications of the fused dense step ``x ← tanh(x·W + b)`` with fixed,
deterministically generated weights.

This module is build-time only — it is lowered once by ``aot.py`` and never
imported on the Rust request path.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import feature_expand, fused_step

#: Result dimension: 256 × f32 = 1024 bytes, the paper's payload size.
DIM = 256
#: Scanned dense steps per result ("complex simulation" depth).
K_STEPS = 8
#: Weight-generation seed (fixed: results must be reproducible across
#: builds — the cache keys on the seed alone).
WEIGHT_SEED = 42


def make_weights(dim: int = DIM, seed: int = WEIGHT_SEED):
    """Deterministic dense weights: W ~ U(-1,1)/sqrt(dim), b ~ U(-0.1,0.1)."""
    rng = np.random.RandomState(seed)
    w = (rng.uniform(-1.0, 1.0, size=(dim, dim)) / np.sqrt(dim)).astype(np.float32)
    b = rng.uniform(-0.1, 0.1, size=(dim,)).astype(np.float32)
    return jnp.asarray(w), jnp.asarray(b)


_W, _B = make_weights()


def partial_result(seeds, *, interpret=True):
    """Batch of seeds (int32[B]) → partial results (f32[B, DIM]).

    Returned as a 1-tuple: the AOT bridge lowers with ``return_tuple=True``
    and the Rust side unwraps with ``to_tuple1`` (see aot.py).
    """
    x = feature_expand(seeds, DIM, interpret=interpret)

    def step(carry, _):
        return fused_step(carry, _W, _B, interpret=interpret), None

    x, _ = jax.lax.scan(step, x, None, length=K_STEPS)
    return (x,)


def partial_result_ref(seeds):
    """Oracle built from the kernel oracles (for model-level tests)."""
    from .kernels import ref

    x = ref.feature_expand_ref(seeds, DIM)
    for _ in range(K_STEPS):
        x = ref.fused_step_ref(x, _W, _B)
    return x

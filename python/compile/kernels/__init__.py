"""Layer-1 Pallas kernels for the "partial result" computation.

The paper's HashMap benchmark "mimics the calculation in a complex
simulation where partial results are stored in a hash-map for later reuse"
(§4.1). These kernels are that calculation: a seed-to-feature expansion and
a fused dense step, lowered with ``interpret=True`` so the CPU PJRT client
(the Rust runtime) can execute the resulting HLO.
"""

from .fused_step import feature_expand, fused_step

__all__ = ["feature_expand", "fused_step"]

"""Pallas kernels: seed → feature expansion and the fused dense step.

TPU-idiomatic structure (DESIGN.md §Hardware-Adaptation):

* ``fused_step`` is a blocked matmul with a fused bias + tanh epilogue.
  The output is tiled ``(bm, bn)``; each program loads an ``(bm, K)``
  activation stripe and a ``(K, bn)`` weight panel into VMEM and feeds the
  MXU-shaped contraction, applying the epilogue before writing back — the
  activation never round-trips to HBM between matmul and nonlinearity.
* ``feature_expand`` is an elementwise VPU-style kernel: one program per
  batch tile, computing ``sin``-mixed features from integer seeds.

Both must run under ``interpret=True`` — real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute (see /opt/xla-example
README). Correctness is pinned against the pure-jnp oracles in ``ref.py``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Golden-ratio-ish mixing constant for the seed expansion (fits in f32
# exactly enough to be deterministic across platforms).
_MIX = 0.6180339887498949


def _fused_step_kernel(x_ref, w_ref, b_ref, o_ref):
    """One output tile: ``o = tanh(x @ w + b)``.

    ``x_ref``: (bm, K) activation stripe in VMEM.
    ``w_ref``: (K, bn) weight panel in VMEM.
    ``b_ref``: (bn,) bias slice.
    ``o_ref``: (bm, bn) output tile.

    The dot feeds the MXU (f32 here; bf16 inputs keep an f32 accumulator
    via ``preferred_element_type``), bias+tanh fuse into the epilogue.
    """
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...].astype(jnp.float32)[None, :]
    o_ref[...] = jnp.tanh(acc).astype(o_ref.dtype)


def _pick_tile(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is ≤ target (tile size heuristic)."""
    t = min(n, target)
    while n % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_step(x, w, b, *, interpret=True):
    """``tanh(x @ w + b)`` as a blocked Pallas kernel.

    x: (B, K), w: (K, N), b: (N,) → (B, N) in ``x.dtype``.
    """
    batch, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch: {k} vs {k2}"
    assert b.shape == (n,)
    bm = _pick_tile(batch, 64)
    bn = _pick_tile(n, 128)
    grid = (batch // bm, n // bn)
    return pl.pallas_call(
        _fused_step_kernel,
        grid=grid,
        in_specs=[
            # Activation stripe: full contraction dimension per tile.
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            # Weight panel.
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            # Bias slice.
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((batch, n), x.dtype),
        interpret=interpret,
    )(x, w, b)


def _feature_expand_kernel(seed_ref, o_ref):
    """One batch tile of the seed expansion.

    ``o[i, j] = sin((seed_i * MIX + j + 1) * MIX * (j + 1))`` — a cheap,
    deterministic, well-spread feature map (the "simulation input").
    """
    dim = o_ref.shape[1]
    seeds = seed_ref[...].astype(jnp.float32)
    j = jax.lax.broadcasted_iota(jnp.float32, (1, dim), 1) + 1.0
    phase = seeds[:, None] * jnp.float32(_MIX) + j
    o_ref[...] = jnp.sin(phase * j * jnp.float32(_MIX)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("dim", "interpret"))
def feature_expand(seeds, dim: int = 256, *, interpret=True):
    """Expand int32 seeds (B,) to f32 features (B, dim)."""
    (batch,) = seeds.shape
    bm = _pick_tile(batch, 64)
    return pl.pallas_call(
        _feature_expand_kernel,
        grid=(batch // bm,),
        in_specs=[pl.BlockSpec((bm,), lambda i: (i,))],
        out_specs=pl.BlockSpec((bm, dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, dim), jnp.float32),
        interpret=interpret,
    )(seeds)

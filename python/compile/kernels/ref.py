"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every kernel in this package must match its oracle under
``numpy.testing.assert_allclose`` across the shape/dtype sweep in
``python/tests/test_kernel.py``.
"""

import jax
import jax.numpy as jnp

_MIX = 0.6180339887498949


def fused_step_ref(x, w, b):
    """Reference ``tanh(x @ w + b)`` with an f32 accumulator."""
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    acc = acc + b.astype(jnp.float32)[None, :]
    return jnp.tanh(acc).astype(x.dtype)


def feature_expand_ref(seeds, dim: int = 256):
    """Reference seed expansion (mirrors the kernel: same op order, same
    f32 constants)."""
    seeds = seeds.astype(jnp.float32)
    j = jax.lax.broadcasted_iota(jnp.float32, (1, dim), 1) + 1.0
    phase = seeds[:, None] * jnp.float32(_MIX) + j
    return jnp.sin(phase * j * jnp.float32(_MIX)).astype(jnp.float32)

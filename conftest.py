"""Repo-root pytest config: make `python/` importable so
`pytest python/tests/` works from the repository root (the Makefile also
supports `cd python && pytest tests/`)."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent / "python"))
